//! Drivers for the paper's figures:
//!   fig1 — attention-pattern similarity (intra-/inter-layer)
//!   fig3 — loss curves: BERT-Base (a), GPT-Base (b), BERT-Large 2/3-level (c)
//!   fig4 — App. B: monotonic growth mapped once vs twice
//!   fig5 — App. F: effect of coalescing (random small init; interp path)
//!   fig6 — App. G: continue training the de-coalesced model
//!   fig7 — App. J: learned (fitted) vs analytic de-coalescing
//!   fig8 — App. K: coalesced model vs LoRA

use anyhow::Result;

use crate::coordinator::experiment::level_cfg;
use crate::coordinator::lora::run_lora;
use crate::coordinator::trainer::Trainer;
use crate::coordinator::{operators, savings_vs_scratch, Harness, LrSchedule, Method};
use crate::info;
use crate::runtime::{init_state, init_theta, Arg, Runtime};
use crate::util::cli::Args;
use crate::util::table::{pct, Table};

use super::common::{emit, opts_from_args, save_curve};

// ---------------------------------------------------------------------------
// Fig. 1 — attention similarity
// ---------------------------------------------------------------------------

pub fn fig1(rt: &Runtime, args: &Args) -> Result<()> {
    let base = "bert_base_sim";
    let steps = args.usize_or("steps", 150);
    let cfg = rt.cfg(base)?.clone();

    // briefly pre-train so attention patterns are non-random
    let mut state = init_state(rt, &cfg, 11)?;
    let mut trainer = Trainer::new(rt, base, 0, 5, 2)?;
    let sched = LrSchedule::new(steps / 10, 1e-3, steps);
    for step in 1..=steps {
        let (s, _) = trainer.step(rt, &state, sched.lr(step), step)?;
        state = s;
    }

    // one probe batch through attn_maps -> [L, H, S, S]
    let exe = rt.exe(&format!("attn_maps__{base}"))?;
    let corpus = crate::data::Corpus::new(cfg.vocab, 0);
    let batch = crate::data::Batcher::validation_set(&cfg, corpus, 1).remove(0);
    let out = rt.call(
        &exe,
        &[Arg::Buf(&state.buf), Arg::I32(&batch.tokens, batch.dims().to_vec())],
    )?;
    let maps = rt.read_f32(&out)?;
    let (l, h, s) = (cfg.n_layer, cfg.n_head, cfg.seq_len);
    let at = |li: usize, hi: usize| -> &[f32] {
        let base_idx = (li * h + hi) * s * s;
        &maps[base_idx..base_idx + s * s]
    };
    let cos = |a: &[f32], b: &[f32]| -> f64 {
        let (mut ab, mut aa, mut bb) = (0f64, 0f64, 0f64);
        for (x, y) in a.iter().zip(b) {
            ab += (*x as f64) * (*y as f64);
            aa += (*x as f64) * (*x as f64);
            bb += (*y as f64) * (*y as f64);
        }
        ab / (aa.sqrt() * bb.sqrt()).max(1e-12)
    };

    // intra-layer: mean pairwise head similarity per layer
    let mut t1 = Table::new(
        "Fig. 1 — intra-layer attention similarity (mean pairwise head cosine)",
        &["Layer", "MeanCos", "MaxPair"],
    );
    for li in 0..l {
        let mut vals = Vec::new();
        for a in 0..h {
            for b in a + 1..h {
                vals.push(cos(at(li, a), at(li, b)));
            }
        }
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let max = vals.iter().cloned().fold(0.0, f64::max);
        t1.row(vec![format!("{}", li + 1), format!("{mean:.3}"), format!("{max:.3}")]);
    }

    // inter-layer: same head, adjacent layers
    let mut t2 = Table::new(
        "Fig. 1 — inter-layer attention similarity (same head, adjacent layers)",
        &["LayerPair", "MeanCos"],
    );
    let mut rand_base = 0.0f64;
    for li in 0..l - 1 {
        let mut vals = Vec::new();
        for hi in 0..h {
            vals.push(cos(at(li, hi), at(li + 1, hi)));
        }
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        t2.row(vec![format!("{}-{}", li + 1, li + 2), format!("{mean:.3}")]);
        // distant-pair baseline: layer 1 vs last layer
        rand_base = cos(at(0, 0), at(l - 1, h - 1));
    }
    info!("fig1: distant-pair baseline cosine = {rand_base:.3}");
    emit("fig1", &[t1, t2])
}

// ---------------------------------------------------------------------------
// Fig. 3 — loss curves + savings summary
// ---------------------------------------------------------------------------

fn fig3_one(rt: &Runtime, args: &Args, id: &str, base: &str, alpha: f32,
            levels: &[usize], default_steps: usize) -> Result<()> {
    let mut opts = opts_from_args(base, default_steps, args);
    opts.alpha = alpha;
    let h = Harness::new(rt, opts.clone());
    let scratch = h.run_method(&Method::Scratch, None)?;
    save_curve(id, &scratch)?;
    let mut t = Table::new(
        &format!("Fig. 3 ({id}) — {base}: V-cycle vs scratch"),
        &["Method", "FinalEval", "Saving(FLOPs)", "Saving(Wall)", "ReachedTarget"],
    );
    let fe = scratch.final_eval(base, 3).unwrap_or(f32::NAN);
    t.row(vec!["Scratch".into(), format!("{fe:.4}"), "0%".into(), "0%".into(), "-".into()]);
    for &k in levels {
        let m = Method::VCycle { levels: k, fit: false };
        let curve = h.run_method(&m, scratch.final_eval(base, 3))?;
        save_curve(id, &curve)?;
        let s = savings_vs_scratch(&scratch, &curve, base);
        let fe = curve.final_eval(base, 3).unwrap_or(f32::NAN);
        t.row(vec![
            m.label(),
            format!("{fe:.4}"),
            pct(s.flops),
            pct(s.wall),
            s.reached.to_string(),
        ]);
    }
    emit(id, &[t])
}

pub fn fig3a(rt: &Runtime, args: &Args) -> Result<()> {
    fig3_one(rt, args, "fig3a", "bert_base_sim", 0.5, &[2], 400)
}
pub fn fig3b(rt: &Runtime, args: &Args) -> Result<()> {
    fig3_one(rt, args, "fig3b", "gpt_base_sim", 0.25, &[2], 400)
}
pub fn fig3c(rt: &Runtime, args: &Args) -> Result<()> {
    fig3_one(rt, args, "fig3c", "bert_large_sim", 0.5, &[2, 3], 300)
}

// ---------------------------------------------------------------------------
// Fig. 4 — App. B: map once vs map twice (monotonic growth)
// ---------------------------------------------------------------------------

pub fn fig4(rt: &Runtime, args: &Args) -> Result<()> {
    // NOTE: the paper uses GPT-Small→Base→Large; we substitute the
    // bert_large_sim 3-level chain, which has the same structure.
    let base = "bert_large_sim";
    let opts = opts_from_args(base, 300, args);
    let h = Harness::new(rt, opts.clone());
    let lv2 = level_cfg(base, 2);
    let lv3 = level_cfg(base, 3);
    let e_small = opts.e_small();

    // mapped once: train lv2, grow (α=1), train base
    let once = h.run_method(&Method::LiGO { fit: false }, None)?;
    save_curve("fig4", &once)?;

    // mapped twice: train lv3, grow to lv2, train lv2, grow to base, train
    let mut run = h.new_run_pub("Mapped twice", &lv3, 7)?;
    let sched = h.sched_pub(e_small);
    h.train_phase(&mut run, e_small / 2, &sched, None, 0.0)?;
    let fresh2 = init_state(rt, rt.cfg(&lv2)?, opts.seed ^ 21)?;
    let st = operators::refine(rt, &lv2, &lv3, &fresh2, &run.state, 1.0, false)?;
    h.transition_pub(&mut run, &lv2, st)?;
    h.train_phase(&mut run, e_small / 2, &sched, None, 0.0)?;
    let fresh1 = init_state(rt, rt.cfg(base)?, opts.seed ^ 22)?;
    let st = operators::refine(rt, base, &lv2, &fresh1, &run.state, 1.0, false)?;
    h.transition_pub(&mut run, base, st)?;
    let budget = (opts.total_steps as f64 * opts.budget_mult) as usize;
    let sched = h.sched_pub(budget);
    h.train_phase(&mut run, budget, &sched, None, 0.0)?;
    let twice = Harness::close_pub(run);
    save_curve("fig4", &twice)?;

    let mut t = Table::new(
        "Fig. 4 (App. B) — monotonic growth: mapped once vs mapped twice",
        &["Chain", "FinalEval", "EvalAt50%Budget"],
    );
    let halfway = |c: &crate::coordinator::Curve| -> f32 {
        let half = c.total_flops * 0.5;
        c.points
            .iter()
            .filter(|p| p.config == base && p.flops >= half)
            .find_map(|p| p.eval_loss)
            .unwrap_or(f32::NAN)
    };
    t.row(vec![
        "small → base (once)".into(),
        format!("{:.4}", once.final_eval(base, 3).unwrap_or(f32::NAN)),
        format!("{:.4}", halfway(&once)),
    ]);
    t.row(vec![
        "tiny → small → base (twice)".into(),
        format!("{:.4}", twice.final_eval(base, 3).unwrap_or(f32::NAN)),
        format!("{:.4}", halfway(&twice)),
    ]);
    emit("fig4", &[t])
}

// ---------------------------------------------------------------------------
// Fig. 5 — App. F: effect of the coalescing operation
// ---------------------------------------------------------------------------

pub fn fig5(rt: &Runtime, args: &Args) -> Result<()> {
    let base = "gpt_base_sim";
    let mut opts = opts_from_args(base, 300, args);
    opts.alpha = 0.25;
    let h = Harness::new(rt, opts.clone());

    // (a) V-cycle with vs without the coalescing link
    let scratch = h.run_method(&Method::Scratch, None)?;
    let with = h.run_method(&Method::VCycle { levels: 2, fit: false },
                            scratch.final_eval(base, 3))?;
    let without = h.run_method(&Method::VCycleRandomSmall, scratch.final_eval(base, 3))?;
    for c in [&scratch, &with, &without] {
        save_curve("fig5", c)?;
    }
    let s_with = savings_vs_scratch(&scratch, &with, base);
    let s_without = savings_vs_scratch(&scratch, &without, base);
    let mut t1 = Table::new(
        "Fig. 5a (App. F) — V-cycle with vs without coalescing",
        &["Variant", "Saving(FLOPs)", "Saving(Wall)", "Drop"],
    );
    t1.row(vec!["with coalescing".into(), pct(s_with.flops), pct(s_with.wall), "-".into()]);
    t1.row(vec![
        "random small init".into(),
        pct(s_without.flops),
        pct(s_without.wall),
        pct(s_with.flops - s_without.flops),
    ]);

    // (b) interpolation loss path between M1 (pre-coalesce) and the
    // de-coalesced model, with vs without coalescing
    let small_cfg = level_cfg(base, 2);
    let e_a = opts.warmup;
    let e_small = opts.e_small();
    let mut run = h.new_run_pub("probe", base, 31)?;
    let sched = h.sched_pub(opts.total_steps);
    h.train_phase(&mut run, e_a, &sched, None, 0.0)?;
    let big_state = operators::interp_states(rt, base, &run.state, &run.state, 0.0)?;

    // trained small model, coalesced init
    let co = operators::coalesce(rt, base, &small_cfg, &run.state)?;
    h.transition_pub(&mut run, &small_cfg, co)?;
    let sched_s = h.sched_pub(e_small);
    h.train_phase(&mut run, e_small / 2, &sched_s, None, 0.0)?;
    let dec_co = operators::refine(rt, base, &small_cfg, &big_state, &run.state, 1.0, false)?;

    // trained small model, random init
    let mut run2 = h.new_run_pub("probe2", &small_cfg, 33)?;
    h.train_phase(&mut run2, e_small / 2, &sched_s, None, 0.0)?;
    let dec_rand = operators::refine(rt, base, &small_cfg, &big_state, &run2.state, 1.0, false)?;

    let trainer = Trainer::new(rt, base, 0, 1, 4)?;
    let mut t2 = Table::new(
        "Fig. 5b (App. F) — interpolation loss path (alpha: M1 -> de-coalesced)",
        &["alpha", "loss (with coalescing)", "loss (random small)"],
    );
    for i in 0..=10 {
        let a = i as f32 / 10.0;
        let p1 = operators::interp_states(rt, base, &big_state, &dec_co, a)?;
        let p2 = operators::interp_states(rt, base, &big_state, &dec_rand, a)?;
        let l1 = trainer.eval(rt, &p1)?;
        let l2 = trainer.eval(rt, &p2)?;
        t2.row(vec![format!("{a:.1}"), format!("{l1:.4}"), format!("{l2:.4}")]);
    }
    emit("fig5", &[t1, t2])
}

// ---------------------------------------------------------------------------
// Fig. 6 — App. G: symmetric neurons of the de-coalesced model
// ---------------------------------------------------------------------------

pub fn fig6(rt: &Runtime, args: &Args) -> Result<()> {
    let base = "gpt_base_sim";
    let opts = opts_from_args(base, 300, args);
    let h = Harness::new(rt, opts.clone());
    let scratch = h.run_method(&Method::Scratch, None)?;
    let dec = h.run_method(&Method::DecoalescedOnly, None)?;
    save_curve("fig6", &scratch)?;
    save_curve("fig6", &dec)?;
    let mut t = Table::new(
        "Fig. 6 (App. G) — continuing the de-coalesced model (α=1, symmetric neurons)",
        &["Run", "FinalEval", "Note"],
    );
    t.row(vec![
        "scratch".into(),
        format!("{:.4}", scratch.final_eval(base, 3).unwrap_or(f32::NAN)),
        "-".into(),
    ]);
    t.row(vec![
        "de-coalesced only".into(),
        format!("{:.4}", dec.final_eval(base, 3).unwrap_or(f32::NAN)),
        "symmetric neurons limit capacity".into(),
    ]);
    emit("fig6", &[t])
}

// ---------------------------------------------------------------------------
// Fig. 7 — App. J: learned transformation
// ---------------------------------------------------------------------------

pub fn fig7(rt: &Runtime, args: &Args) -> Result<()> {
    let base = "gpt_base_sim";
    let mut opts = opts_from_args(base, 300, args);
    opts.alpha = 0.25;
    let h = Harness::new(rt, opts.clone());
    let scratch = h.run_method(&Method::Scratch, None)?;
    let target = scratch.final_eval(base, 3);
    let plain = h.run_method(&Method::VCycle { levels: 2, fit: false }, target)?;
    let fitted = h.run_method(&Method::VCycle { levels: 2, fit: true }, target)?;
    for c in [&scratch, &plain, &fitted] {
        save_curve("fig7", c)?;
    }
    let sp = savings_vs_scratch(&scratch, &plain, base);
    let sf = savings_vs_scratch(&scratch, &fitted, base);
    // initial loss right after the refine transition (first eval of the
    // final phase)
    let first_eval_final = |c: &crate::coordinator::Curve| -> f32 {
        let last_phase = c.points.last().map(|p| p.phase).unwrap_or(0);
        c.points
            .iter()
            .filter(|p| p.phase == last_phase)
            .find_map(|p| p.eval_loss)
            .unwrap_or(f32::NAN)
    };
    let mut t = Table::new(
        "Fig. 7 (App. J) — analytic vs learned (least-squares) de-coalescing",
        &["Variant", "LossAfterRefine", "FinalEval", "Saving(FLOPs)"],
    );
    t.row(vec![
        "analytic G".into(),
        format!("{:.4}", first_eval_final(&plain)),
        format!("{:.4}", plain.final_eval(base, 3).unwrap_or(f32::NAN)),
        pct(sp.flops),
    ]);
    t.row(vec![
        "learned G (lstsq)".into(),
        format!("{:.4}", first_eval_final(&fitted)),
        format!("{:.4}", fitted.final_eval(base, 3).unwrap_or(f32::NAN)),
        pct(sf.flops),
    ]);
    emit("fig7", &[t])
}

// ---------------------------------------------------------------------------
// Fig. 8 — App. K: coalesced model vs LoRA
// ---------------------------------------------------------------------------

pub fn fig8(rt: &Runtime, args: &Args) -> Result<()> {
    let base = "bert_base_sim";
    let small_cfg = level_cfg(base, 2);
    let steps = args.usize_or("steps", 200);
    let opts = opts_from_args(base, steps, args);
    let h = Harness::new(rt, opts.clone());

    // coalesced model: coalesce a fresh base model, train the small model
    let mut run = h.new_run_pub("Coalesced BERT", base, 41)?;
    let co = operators::coalesce(rt, base, &small_cfg, &run.state)?;
    h.transition_pub(&mut run, &small_cfg, co)?;
    let sched = h.sched_pub(steps);
    h.train_phase(&mut run, steps, &sched, None, 0.0)?;
    let coalesced = Harness::close_pub(run);
    save_curve("fig8", &coalesced)?;

    // LoRA on the frozen fresh base model
    let theta = init_theta(rt.cfg(base)?, opts.seed ^ 1);
    let lora = run_lora(rt, base, &theta, steps, opts.peak_lr, opts.eval_every, 4,
                        opts.seed ^ 0x10A)?;
    save_curve("fig8", &lora.curve)?;

    let last_eval = |c: &crate::coordinator::Curve| {
        c.points.iter().rev().find_map(|p| p.eval_loss).unwrap_or(f32::NAN)
    };
    let mut t = Table::new(
        "Fig. 8 (App. K) — coalesced BERT vs BERT + LoRA (same step budget)",
        &["Run", "FinalEval", "TotalGFLOPs", "GFLOPs/step"],
    );
    t.row(vec![
        "Coalesced BERT".into(),
        format!("{:.4}", last_eval(&coalesced)),
        format!("{:.2}", coalesced.total_flops / 1e9),
        format!("{:.3}", coalesced.total_flops / steps as f64 / 1e9),
    ]);
    t.row(vec![
        "BERT-Base + LoRA".into(),
        format!("{:.4}", last_eval(&lora.curve)),
        format!("{:.2}", lora.curve.total_flops / 1e9),
        format!("{:.3}", lora.curve.total_flops / steps as f64 / 1e9),
    ]);
    emit("fig8", &[t])
}
