//! CI bench gate: timed train-step smoke benches on the reference backend.
//!
//! Measures mean train-step wall time per config, writes a JSON report
//! (the `BENCH_pr.json` CI artifact), and — when `--baseline` is given —
//! exits nonzero if any config regressed more than `--max-regress`
//! (default 0.5 = +50%) over the checked-in ceiling.
//!
//! ```sh
//! cargo run --release --example bench_ci -- \
//!     --out BENCH_pr.json --baseline ci/bench_baseline.json
//! ```

use std::time::Duration;

use anyhow::{Context, Result};
use multilevel::coordinator::{
    synthetic_trace, GenerateRequest, Generator, ServeEngine, ServeOpts, SpecDecoder, Trainer,
    TrafficSpec,
};
use multilevel::obs;
use multilevel::runtime::reference::simd;
use multilevel::runtime::registry::SPEC_K;
use multilevel::runtime::{init_state, init_theta, Arg, Checkpoint, Runtime};
use multilevel::util::bench;
use multilevel::util::cli::Args;
use multilevel::util::json::{arr, num, obj, s, Json};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

/// One report entry: row label, timing stats, and — where the analytic
/// model covers the loop — FLOPs per iteration (for GFLOP/s + MFU).
type Row = (String, bench::Stats, Option<f64>);

/// Prefill + steady-state `decode_step` rows for one causal config
/// (the serving path's tokens/sec). Sharded runtimes tag their rows with
/// `suffix` (e.g. `@r4`) and skip the prefill row — the gate tracks the
/// sharded decode step specifically.
fn decode_bench_rows(
    rt: &Runtime,
    name: &str,
    suffix: &str,
    budget: Duration,
    rows: &mut Vec<Row>,
) -> Result<()> {
    let cfg = rt.cfg(name)?.clone();
    let theta = init_theta(&cfg, 1);
    let prefill = rt.exe(&format!("prefill__{name}"))?;
    let decode = rt.exe(&format!("decode_step__{name}"))?;
    let (b, seq) = (cfg.batch, cfg.seq_len);
    let plen = (seq / 2).max(1);
    let corpus = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(7);
    let mut tokens = Vec::with_capacity(b * seq);
    for _ in 0..b {
        tokens.extend(corpus.sequence(seq, &mut rng));
    }
    let lens: Vec<i32> = vec![plen as i32; b];
    let pargs = [
        Arg::F32(&theta, vec![theta.len()]),
        Arg::I32(&tokens, vec![b, seq]),
        Arg::I32(&lens, vec![b]),
    ];
    let recs = rt.call(&prefill, &pargs)?; // prepare + warm
    if suffix.is_empty() {
        let label = format!("prefill__{name}");
        let stats = bench::run(&label, budget, || {
            bench::black_box(rt.call(&prefill, &pargs).unwrap());
        });
        println!(
            "    -> {:.0} prompt tokens/s ({b} requests x {plen} tokens per call)",
            (b * plen) as f64 / stats.mean.as_secs_f64()
        );
        rows.push((label, stats, None));
    }
    // steady-state decode: one token for every request at a fixed
    // mid-context cache length (O(len) attention, zero-alloc arena path)
    let next: Vec<i32> = (0..b).map(|i| tokens[i * seq + plen - 1]).collect();
    let dargs = [
        Arg::F32(&theta, vec![theta.len()]),
        Arg::Buf(&recs),
        Arg::I32(&next, vec![b]),
        Arg::I32(&lens, vec![b]),
    ];
    bench::black_box(rt.call(&decode, &dargs)?); // warm
    let label = format!("decode_step__{name}{suffix}");
    let stats = bench::run(&label, budget, || {
        bench::black_box(rt.call(&decode, &dargs).unwrap());
    });
    println!(
        "    -> {:.0} tokens/s ({b} requests per step)",
        b as f64 / stats.mean.as_secs_f64()
    );
    rows.push((label, stats, None));
    Ok(())
}

/// One full continuous-batching serve of a small fixed mixed-length
/// trace: queueing, slot churn, ragged prefill and ragged decode sweeps —
/// the engine-level serving cost rather than a single artifact call.
/// Deterministic by construction, so every iteration does identical work.
fn serve_bench_row(
    rt: &Runtime,
    name: &str,
    suffix: &str,
    budget: Duration,
    rows: &mut Vec<Row>,
) -> Result<()> {
    let cfg = rt.cfg(name)?.clone();
    let theta = init_theta(&cfg, 1);
    let spec = TrafficSpec {
        seed: 11,
        requests: 6,
        mean_interarrival: 1.5,
        prompt_lens: (1, cfg.seq_len / 2),
        gen_tokens: (1, 6),
    };
    let trace = synthetic_trace(&cfg, &spec)?;
    let eng = ServeEngine::new(
        rt,
        name,
        ServeOpts { max_queue: spec.requests, ..ServeOpts::default() },
    )?;
    let warm = eng.run(rt, &theta, &trace)?; // prepare + warm
    let label = format!("serve__{name}{suffix}");
    let stats = bench::run(&label, budget, || {
        bench::black_box(eng.run(rt, &theta, &trace).unwrap());
    });
    println!(
        "    -> {} requests, {} tokens over {} engine steps per serve",
        trace.len(),
        warm.generated_tokens,
        warm.steps
    );
    rows.push((label, stats, None));
    Ok(())
}

/// Speculative decoding vs plain greedy decoding on the same prompts in
/// the same run, so the printed speedup and acceptance rate are measured,
/// never assumed. Only the speculative row is gated; its ceiling must
/// hold even at zero acceptance (an untrained theta drafts poorly, and a
/// rejected round still commits one token per verify call).
fn spec_bench_row(
    rt: &Runtime,
    name: &str,
    suffix: &str,
    budget: Duration,
    rows: &mut Vec<Row>,
) -> Result<()> {
    let cfg = rt.cfg(name)?.clone();
    let theta = init_theta(&cfg, 1);
    let (b, seq) = (cfg.batch, cfg.seq_len);
    let plen = (seq / 4).max(1);
    let gen = (seq / 4).max(2);
    let corpus = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(7);
    let mut prompts = Vec::with_capacity(b * plen);
    for _ in 0..b {
        prompts.extend(corpus.sequence(plen, &mut rng));
    }
    let dec = SpecDecoder::new(rt, name, 2, SPEC_K)?;
    let plain = Generator::new(rt, name)?;
    let req = || GenerateRequest::new(&prompts, plen).max_new_tokens(gen);
    let warm = dec.generate(rt, &theta, req())?; // prepare + warm
    let label = format!("spec_decode__{name}{suffix}");
    let stats = bench::run(&label, budget, || {
        bench::black_box(dec.generate(rt, &theta, req()).unwrap());
    });
    bench::black_box(plain.generate(rt, &theta, req())?); // warm
    let pstats = bench::run(&format!("plain_decode__{name}{suffix}"), budget, || {
        bench::black_box(plain.generate(rt, &theta, req()).unwrap());
    });
    let toks = (b * gen) as f64;
    let (spec_s, plain_s) = (stats.mean.as_secs_f64(), pstats.mean.as_secs_f64());
    println!(
        "    -> {:.0} tokens/s speculative vs {:.0} plain ({:.2}x speedup); \
         {} of {} drafts accepted ({:.0}% acceptance, k={})",
        toks / spec_s.max(1e-9),
        toks / plain_s.max(1e-9),
        plain_s / spec_s.max(1e-9),
        warm.stats.accepted,
        warm.stats.drafted,
        warm.stats.acceptance_rate() * 100.0,
        dec.k()
    );
    rows.push((label, stats, None));
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let out_path = args.get_or("out", "BENCH_pr.json").to_string();
    let baseline_path = args.get("baseline").map(str::to_string);
    let max_regress = args.f64_or("max-regress", 0.5);
    let budget = Duration::from_millis(args.u64_or("budget-ms", 1200));
    let configs: Vec<String> = args
        .get_or("configs", "gpt_nano,bert_nano,gpt_base_sim,bert_base_sim")
        .split(',')
        .map(str::to_string)
        .collect();

    let rt = Runtime::reference();
    println!("== bench_ci on {} ==", rt.device_info());
    // Calibrate the roofline under the startup kernel tier before any
    // tier flip below — it is cached once per process.
    let roofline = obs::metrics::roofline_flops();

    let mut rows: Vec<Row> = Vec::new();
    for name in &configs {
        let cfg = rt.cfg(name)?.clone();
        let mut state = init_state(&rt, &cfg, 1)?;
        let mut trainer = Trainer::new(&rt, name, 0, 2, 1)?;
        let (warm, _) = trainer.step(&rt, &state, 1e-3, 1)?; // prepare + warm
        state = warm;
        let mut step = 1usize;
        let stats = bench::run(&format!("train_step {name}"), budget, || {
            step += 1;
            let (next, _) = trainer.step(&rt, &state, 1e-3, step).unwrap();
            state = next;
        });
        rows.push((name.clone(), stats, Some(cfg.flops_train_step)));
    }

    // same-run scalar-tier rerun of the GEMM-bound gpt_base_sim train step,
    // so the SIMD speedup in the log is measured, never assumed (the row is
    // recorded in the report but has no baseline entry, hence never gated)
    let tier0 = simd::tier();
    if tier0 != simd::Tier::Scalar {
        let simd_ms = rows
            .iter()
            .find(|(n, _, _)| n == "gpt_base_sim")
            .map(|(_, st, _)| st.mean.as_secs_f64() * 1e3);
        simd::set_tier(simd::Tier::Scalar).expect("scalar tier is always supported");
        let name = "gpt_base_sim";
        let cfg = rt.cfg(name)?.clone();
        let mut state = init_state(&rt, &cfg, 1)?;
        let mut trainer = Trainer::new(&rt, name, 0, 2, 1)?;
        let (warm, _) = trainer.step(&rt, &state, 1e-3, 1)?; // prepare + warm
        state = warm;
        let mut step = 1usize;
        let label = "scalar__gpt_base_sim";
        let stats = bench::run(label, budget, || {
            step += 1;
            let (next, _) = trainer.step(&rt, &state, 1e-3, step).unwrap();
            state = next;
        });
        simd::set_tier(tier0).expect("restoring the startup tier");
        let scalar_ms = stats.mean.as_secs_f64() * 1e3;
        if let Some(simd_ms) = simd_ms {
            println!(
                "    -> {} tier {simd_ms:.2} ms vs scalar {scalar_ms:.2} ms per step: \
                 {:.2}x speedup in this run",
                tier0.name(),
                scalar_ms / simd_ms.max(1e-9)
            );
        }
        rows.push((label.to_string(), stats, Some(cfg.flops_train_step)));
    }

    // tracing overhead: the same gpt_base_sim train step once with obs
    // disabled (gated — the disabled path must stay within the plain
    // train-step ceiling, pinning "zero overhead when off") and once with
    // tracing + metrics enabled (printed for the log, never gated)
    {
        let name = "gpt_base_sim";
        let mut state = init_state(&rt, rt.cfg(name)?, 1)?;
        let mut trainer = Trainer::new(&rt, name, 0, 2, 1)?;
        let (warm, _) = trainer.step(&rt, &state, 1e-3, 1)?; // prepare + warm
        state = warm;
        let mut step = 1usize;
        let label = format!("trace_overhead__{name}");
        let stats = bench::run(&label, budget, || {
            step += 1;
            let (next, _) = trainer.step(&rt, &state, 1e-3, step).unwrap();
            state = next;
        });
        let disabled_ms = stats.mean.as_secs_f64() * 1e3;
        rows.push((label, stats, Some(rt.cfg(name)?.flops_train_step)));
        obs::set_tracing(true);
        obs::set_metrics(true);
        let on = bench::run(&format!("trace_overhead__{name} (enabled)"), budget, || {
            step += 1;
            let (next, _) = trainer.step(&rt, &state, 1e-3, step).unwrap();
            state = next;
        });
        obs::set_tracing(false);
        obs::set_metrics(false);
        obs::tracer::reset_spans();
        obs::metrics::reset_metrics();
        let enabled_ms = on.mean.as_secs_f64() * 1e3;
        println!(
            "    -> tracing enabled: {enabled_ms:.2} ms vs {disabled_ms:.2} ms disabled \
             ({:+.1}% — informational, not gated)",
            (enabled_ms / disabled_ms.max(1e-9) - 1.0) * 100.0
        );
    }

    // checkpoint save + load round trip on the full gpt_base_sim state:
    // atomic write (tmp + fsync + rename), then parse + CRC verify — the
    // fixed cost a kill-and-resume run pays at every snapshot cadence
    {
        let cfg = rt.cfg("gpt_base_sim")?.clone();
        let host = init_state(&rt, &cfg, 1)?.to_host(&rt)?;
        let dir = multilevel::util::tmp::TempDir::new("bench_ckpt");
        let path = dir.file("bench.ckpt");
        let ck = Checkpoint {
            kind: "train".into(),
            config: cfg.name.clone(),
            n_params: cfg.n_params,
            level: 1,
            phase: 1,
            step: 1,
            flops: 0.0,
            replicas: 1,
            seed: 1,
            stream_cursor: [1, 2, 3, 4],
            extra: Json::Null,
            vectors: vec![("state".into(), host)],
        };
        ck.save(&path)?; // warm (creates the file once)
        let label = "ckpt_save_load__gpt_base_sim";
        let stats = bench::run(label, budget, || {
            ck.save(&path).unwrap();
            bench::black_box(Checkpoint::load(&path).unwrap());
        });
        rows.push((label.to_string(), stats, None));
    }

    // serving path: prefill throughput + steady-state decode tokens/sec
    let decode_configs: Vec<String> = args
        .get_or("decode-configs", "gpt_base_sim")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    for name in &decode_configs {
        decode_bench_rows(&rt, name, "", budget, &mut rows)?;
        serve_bench_row(&rt, name, "", budget, &mut rows)?;
        spec_bench_row(&rt, name, "", budget, &mut rows)?;
    }

    // sharded train step: the data-parallel grad → all-reduce → AdamW path
    // (row name `<config>@r<R>`, gated like any other entry)
    let replicas = args.usize_or("replicas", 4);
    let sharded_configs: Vec<String> = args
        .get_or("sharded-configs", "gpt_base_sim")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if replicas > 1 {
        let srt = Runtime::sharded(replicas);
        println!("-- sharded: {} --", srt.device_info());
        for name in &sharded_configs {
            let flops = srt.cfg(name)?.flops_train_step;
            let mut state = init_state(&srt, srt.cfg(name)?, 1)?;
            let mut trainer = Trainer::new(&srt, name, 0, 2, 1)?;
            let (warm, _) = trainer.step(&srt, &state, 1e-3, 1)?;
            state = warm;
            let mut step = 1usize;
            let label = format!("{name}@r{replicas}");
            let stats = bench::run(&format!("train_step {label}"), budget, || {
                step += 1;
                let (next, _) = trainer.step(&srt, &state, 1e-3, step).unwrap();
                state = next;
            });
            rows.push((label, stats, Some(flops)));
        }
        // sharded forward-only eval throughput (the data-parallel
        // eval_loss path: per-shard losses + weighted fixed-order combine)
        for name in args.get_or("eval-configs", "bert_base_sim").split(',') {
            if name.is_empty() {
                continue;
            }
            let state = init_state(&srt, srt.cfg(name)?, 1)?;
            let trainer = Trainer::new(&srt, name, 0, 2, 1)?;
            trainer.eval(&srt, &state)?; // prepare + warm
            let label = format!("eval_loss__{name}@r{replicas}");
            let stats = bench::run(&label, budget, || {
                trainer.eval(&srt, &state).unwrap();
            });
            rows.push((label, stats, None));
        }
        // sharded decode: requests split across replicas, records
        // concatenated back in replica order (bit-identical to serial)
        for name in &decode_configs {
            decode_bench_rows(&srt, name, &format!("@r{replicas}"), budget, &mut rows)?;
            serve_bench_row(&srt, name, &format!("@r{replicas}"), budget, &mut rows)?;
            spec_bench_row(&srt, name, &format!("@r{replicas}"), budget, &mut rows)?;
        }
    }

    // roofline-normalized per-row summary: ms plus achieved GFLOP/s and MFU
    // for every row the analytic FLOPs model covers
    println!(
        "-- rows ({} kernel tier, {:.2} GFLOP/s calibrated roofline) --",
        simd::tier().name(),
        roofline / 1e9
    );
    for (name, st, flops) in &rows {
        let ms = st.mean.as_secs_f64() * 1e3;
        match flops {
            Some(f) => {
                let achieved = f / st.mean.as_secs_f64();
                println!(
                    "  {name:32} {ms:10.2} ms  {:8.2} GFLOP/s  mfu {:.3}",
                    achieved / 1e9,
                    achieved / roofline
                );
            }
            None => println!("  {name:32} {ms:10.2} ms"),
        }
    }

    let report = obj(vec![
        ("schema", num(1.0)),
        ("device", s(&rt.device_info())),
        ("threads", num(threadpool::threads() as f64)),
        ("kernel", s(simd::tier().name())),
        ("roofline_gflops", num(roofline / 1e9)),
        (
            "results",
            arr(rows
                .iter()
                .map(|(name, st, flops)| {
                    let ms = st.mean.as_secs_f64() * 1e3;
                    let mut fields = vec![
                        ("config", s(name)),
                        // generic per-entry mean (entries now cover eval
                        // loops too); "train_step_ms" kept as an alias so
                        // older tooling reading the report keeps working
                        ("ms", num(ms)),
                        ("train_step_ms", num(ms)),
                        ("p50_ms", num(st.p50.as_secs_f64() * 1e3)),
                        ("min_ms", num(st.min.as_secs_f64() * 1e3)),
                        ("iters", num(st.iters as f64)),
                    ];
                    if let Some(f) = flops {
                        let achieved = f / st.mean.as_secs_f64();
                        fields.push(("gflops", num(achieved / 1e9)));
                        fields.push(("mfu", num(achieved / roofline)));
                    }
                    obj(fields)
                })
                .collect()),
        ),
    ]);
    std::fs::write(&out_path, format!("{report}\n"))
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");

    let Some(bp) = baseline_path else {
        return Ok(());
    };
    let text = std::fs::read_to_string(&bp).with_context(|| format!("reading {bp}"))?;
    let base = Json::parse(&text).with_context(|| format!("parsing {bp}"))?;
    let empty: &[Json] = &[];
    let baseline_rows = base.get("results").as_arr().unwrap_or(empty);
    println!("-- gate: max allowed regression +{:.0}% over {bp} --", max_regress * 100.0);
    let mut failures = Vec::new();
    for (name, st, _) in &rows {
        let got_ms = st.mean.as_secs_f64() * 1e3;
        let base_ms = baseline_rows
            .iter()
            .find(|e| e.get("config").as_str() == Some(name.as_str()))
            .and_then(|e| e.get("ms").as_f64().or_else(|| e.get("train_step_ms").as_f64()));
        match base_ms {
            None => println!("  {name:16} {got_ms:10.2} ms  (no baseline entry — recorded only)"),
            Some(b) => {
                let limit = b * (1.0 + max_regress);
                let verdict = if got_ms > limit {
                    failures.push(name.clone());
                    "REGRESSED"
                } else {
                    "ok"
                };
                // speedup vs the checked-in ceiling, so a regression is
                // diagnosable from the CI log alone (>1.0 = faster)
                let speedup = b / got_ms;
                println!(
                    "  {name:32} {got_ms:10.2} ms  baseline {b:.2} ms  limit {limit:.2} ms  \
                     speedup {speedup:5.2}x  {verdict}"
                );
            }
        }
    }
    if !failures.is_empty() {
        eprintln!("bench gate failed for: {}", failures.join(", "));
        std::process::exit(1);
    }
    Ok(())
}
