//! Scenario: the paper's BERT-Large workflow — a 3-level V-cycle with
//! checkpointed level transitions, then downstream probe fine-tuning.
//! This is the workflow of Fig. 3c / Table 4 driven through the public API.
//!
//!     cargo run --release --example multilevel_bert -- [--steps N]

use anyhow::Result;
use multilevel::coordinator::finetune::finetune_all_tasks;
use multilevel::coordinator::{Harness, Method, RunOpts};
use multilevel::runtime::{save_checkpoint, Runtime};
use multilevel::util::cli::Args;
use multilevel::util::table::mean_std;

fn main() -> Result<()> {
    multilevel::util::logger::init().map_err(anyhow::Error::msg)?;
    let args = Args::parse();
    let steps = args.usize_or("steps", 160);
    let rt = Runtime::load_default()?;

    let base = "bert_large_sim";
    let mut opts = RunOpts::quick(base, steps);
    opts.alpha = 0.5; // paper: α = 0.5 for BERT
    opts.budget_mult = 1.0;
    let h = Harness::new(&rt, opts);

    println!("3-level V-cycle on {base} (L12-H12 → L6-H6 → L3-H3)…");
    let (curve, state) = h.run_method_full(&Method::VCycle { levels: 3, fit: false })?;
    println!(
        "final eval {:.4} after {:.1} GFLOPs / {:.0}s",
        curve.final_eval(base, 3).unwrap_or(f32::NAN),
        curve.total_flops / 1e9,
        curve.total_wall
    );

    // checkpoint the pre-trained backbone (App. C: resume = parameter I/O)
    let cfg = rt.cfg(base)?.clone();
    let theta = state.theta(&rt)?;
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("bert_large_sim.ckpt");
    save_checkpoint(&ckpt, &cfg, &theta)?;
    println!("checkpoint -> {ckpt:?} ({} MB)", theta.len() * 4 / 1_000_000);

    // downstream probes (GLUE substitute), 2 seeds for speed
    let results = finetune_all_tasks(&rt, base, &theta, 3, 2, 30, 3e-3)?;
    for r in &results {
        println!(
            "probe task {}: acc {} (seeds: {:?})",
            r.task,
            mean_std(&r.accs),
            r.accs.iter().map(|a| format!("{a:.1}")).collect::<Vec<_>>()
        );
    }
    Ok(())
}
