//! Quickstart: load the runtime, train a nano GPT a few steps, run one
//! V-cycle (coalesce → train small → de-coalesce + interpolate), and print
//! losses. Mirrors README §Quickstart.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use multilevel::coordinator::{operators, LrSchedule, Trainer};
use multilevel::runtime::{init_state, Runtime};

fn main() -> Result<()> {
    // 1. default runtime: reference backend (or PJRT over AOT artifacts
    //    when built with `--features pjrt` and `make artifacts` has run)
    let rt = Runtime::load_default()?;
    println!("platform = {}", rt.platform_name());

    // 2. fresh level-1 model
    let base = "gpt_nano";
    let cfg = rt.cfg(base)?.clone();
    println!("{base}: {} params, {:.1} MFLOP/step", cfg.n_params, cfg.flops_train_step / 1e6);
    let mut state = init_state(&rt, &cfg, 42)?;

    // 3. warm up the large model (E_a), then coalesce to level 2
    let mut trainer = Trainer::new(&rt, base, 0, 1, 2)?;
    let sched = LrSchedule::new(5, 1e-3, 200);
    for step in 1..=20 {
        let (s, loss) = trainer.step(&rt, &state, sched.lr(step), step)?;
        state = s;
        if step % 10 == 0 {
            println!("  [L1 warmup] step {step:3}  loss {loss:.4}");
        }
    }
    let saved_big = operators::interp_states(&rt, base, &state, &state, 0.0)?;
    let small_cfg = "gpt_nano_lv2";
    let mut small = operators::coalesce(&rt, base, small_cfg, &state)?;
    println!("coalesced {} -> {} params", cfg.n_params, small.n_params);

    // 4. train the cheap small model (fast convergence phase)
    let mut small_trainer = Trainer::new(&rt, small_cfg, 0, 2, 2)?;
    for step in 1..=60 {
        let (s, loss) = small_trainer.step(&rt, &small, sched.lr(step), step)?;
        small = s;
        if step % 20 == 0 {
            println!("  [L2] step {step:3}  loss {loss:.4}");
        }
    }

    // 5. de-coalesce + interpolate back into the large model (α = 0.25)
    state = operators::refine(&rt, base, small_cfg, &saved_big, &small, 0.25, false)?;
    let eval = trainer.eval(&rt, &state)?;
    println!("after refine: large-model eval loss = {eval:.4}");

    // 6. continue training the interpolated large model
    for step in 1..=20 {
        let (s, loss) = trainer.step(&rt, &state, sched.lr(step), step)?;
        state = s;
        if step % 10 == 0 {
            println!("  [L1 resume] step {step:3}  loss {loss:.4}");
        }
    }
    println!("final eval = {:.4}", trainer.eval(&rt, &state)?);
    Ok(())
}
