//! End-to-end validation driver (DESIGN.md §End-to-end): pre-train the
//! largest config (`gpt_e2e`, ~6.4M params — the largest a single CPU core
//! trains in reasonable time; a hardware-gated substitution for the system
//! target of ~100M, see DESIGN.md) for a few hundred steps with the V-cycle
//! and compare against training from scratch, logging both loss curves.
//!
//!     cargo run --release --example e2e_train -- [--steps N] [--out results/e2e]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use multilevel::coordinator::{savings_vs_scratch, Harness, Method, RunOpts};
use multilevel::util::cli::Args;

fn main() -> Result<()> {
    multilevel::util::logger::init().map_err(anyhow::Error::msg)?;
    let args = Args::parse();
    let steps = args.usize_or("steps", 240);
    let rt = multilevel::runtime::Runtime::load_default()?;

    let base = "gpt_e2e";
    let cfg = rt.cfg(base)?;
    println!(
        "e2e: {base} — {} params ({:.1}M), {:.2} GFLOP/step, {} steps budget",
        cfg.n_params,
        cfg.n_params as f64 / 1e6,
        cfg.flops_train_step / 1e9,
        steps
    );

    let mut opts = RunOpts::quick(base, steps);
    opts.alpha = 0.25;
    opts.seed = args.u64_or("seed", 7);
    opts.eval_every = (steps / 12).max(5);
    opts.budget_mult = 1.0;
    let h = Harness::new(&rt, opts.clone());

    let t0 = std::time::Instant::now();
    let scratch = h.run_method(&Method::Scratch, None)?;
    println!(
        "scratch: final eval {:.4}, {:.1} GFLOPs, {:.0}s",
        scratch.final_eval(base, 3).unwrap_or(f32::NAN),
        scratch.total_flops / 1e9,
        scratch.total_wall
    );
    let vcycle = h.run_method(&Method::VCycle { levels: 2, fit: false }, None)?;
    println!(
        "v-cycle: final eval {:.4}, {:.1} GFLOPs, {:.0}s",
        vcycle.final_eval(base, 3).unwrap_or(f32::NAN),
        vcycle.total_flops / 1e9,
        vcycle.total_wall
    );
    let s = savings_vs_scratch(&scratch, &vcycle, base);
    println!(
        "savings at scratch target ({:.4}): FLOPs {:+.1}%  walltime {:+.1}%  (reached={})",
        s.target,
        s.flops * 100.0,
        s.wall * 100.0,
        s.reached
    );

    let out = std::path::PathBuf::from(args.get_or("out", "results/e2e"));
    std::fs::create_dir_all(&out)?;
    scratch.write_csv(&out.join("scratch.csv"))?;
    vcycle.write_csv(&out.join("vcycle.csv"))?;
    println!("curves -> {out:?} (total {:.0}s)", t0.elapsed().as_secs_f64());
    Ok(())
}
