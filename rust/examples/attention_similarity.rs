//! Scenario: reproduce the paper's motivating observation (Fig. 1) — the
//! intra- and inter-layer similarity of attention patterns that justifies
//! coalescing — through the public API's attention-map probe artifact.
//!
//!     cargo run --release --example attention_similarity -- [--steps N]

use anyhow::Result;
use multilevel::coordinator::{LrSchedule, Trainer};
use multilevel::data::{Batcher, Corpus};
use multilevel::runtime::{init_state, Arg, Runtime};
use multilevel::util::cli::Args;

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut ab, mut aa, mut bb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b) {
        ab += (*x as f64) * (*y as f64);
        aa += (*x as f64) * (*x as f64);
        bb += (*y as f64) * (*y as f64);
    }
    ab / (aa.sqrt() * bb.sqrt()).max(1e-12)
}

fn main() -> Result<()> {
    multilevel::util::logger::init().map_err(anyhow::Error::msg)?;
    let args = Args::parse();
    let steps = args.usize_or("steps", 120);
    let rt = Runtime::load_default()?;
    let base = "bert_base_sim";
    let cfg = rt.cfg(base)?.clone();

    // train briefly so attention is structured, not random
    let mut state = init_state(&rt, &cfg, 3)?;
    let mut trainer = Trainer::new(&rt, base, 0, 4, 2)?;
    let sched = LrSchedule::new(steps / 10, 1e-3, steps);
    for step in 1..=steps {
        let (s, _) = trainer.step(&rt, &state, sched.lr(step), step)?;
        state = s;
    }

    // probe: attention maps [L, H, S, S] for one validation sentence
    let exe = rt.exe(&format!("attn_maps__{base}"))?;
    let batch = Batcher::validation_set(&cfg, Corpus::new(cfg.vocab, 0), 1).remove(0);
    let out = rt.call(
        &exe,
        &[Arg::Buf(&state.buf), Arg::I32(&batch.tokens, batch.dims().to_vec())],
    )?;
    let maps = rt.read_f32(&out)?;
    let (l, h, s) = (cfg.n_layer, cfg.n_head, cfg.seq_len);
    let at = |li: usize, hi: usize| &maps[(li * h + hi) * s * s..][..s * s];

    println!("intra-layer head-pair cosine (layer 4 of {l}):");
    let li = l / 2;
    for a in 0..h.min(4) {
        for b in a + 1..h.min(4) {
            println!("  L{li} H{a} vs H{b}: {:.3}", cosine(at(li, a), at(li, b)));
        }
    }
    println!("inter-layer same-head cosine:");
    for li in 0..l - 1 {
        let mean: f64 =
            (0..h).map(|hi| cosine(at(li, hi), at(li + 1, hi))).sum::<f64>() / h as f64;
        println!("  L{} vs L{}: {mean:.3}", li + 1, li + 2);
    }
    println!("distant-pair baseline (L1H1 vs L{l}H{h}): {:.3}",
             cosine(at(0, 0), at(l - 1, h - 1)));
    Ok(())
}
