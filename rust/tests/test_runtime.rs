//! Integration tests: the full artifact loop — manifest → prepare → execute
//! — over the nano configs on the [`ReferenceBackend`] (no XLA device, no
//! `make artifacts` needed). Device-requiring coverage is gated behind the
//! `pjrt` cargo feature at the bottom of this file.
//!
//! [`ReferenceBackend`]: multilevel::runtime::ReferenceBackend

use multilevel::coordinator::{operators, LrSchedule, Trainer};
use multilevel::runtime::{init_state, Runtime};

fn rt() -> Runtime {
    Runtime::reference()
}

#[test]
fn manifest_loads_and_validates() {
    let rt = rt();
    assert!(rt.manifest.configs.len() >= 20);
    assert!(rt.manifest.artifacts.len() >= 100);
    let cfg = rt.cfg("gpt_nano").unwrap();
    assert_eq!(cfg.n_layer, 2);
    assert_eq!(cfg.d_model, cfg.n_head * cfg.head_dim);
    // layout covers theta exactly
    let total: usize = cfg.layout.iter().map(|p| p.size()).sum();
    assert_eq!(total, cfg.n_params);
    rt.manifest.validate().unwrap();
}

#[test]
fn unknown_artifact_and_config_error_cleanly() {
    let rt = rt();
    assert!(rt.cfg("no_such_config").is_err());
    assert!(rt.exe("train_step__no_such_config").is_err());
    // arity mismatch is rejected before execution
    let exe = rt.exe("interp__gpt_nano").unwrap();
    assert!(rt.call(&exe, &[]).is_err());
}

#[test]
fn train_step_reduces_loss_gpt_nano() {
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let mut state = init_state(&rt, &cfg, 42).unwrap();
    let mut trainer = Trainer::new(&rt, "gpt_nano", 0, 7, 2).unwrap();
    let sched = LrSchedule::new(5, 2e-3, 80);
    let first = trainer.eval(&rt, &state).unwrap();
    for step in 1..=80 {
        let (s, loss) = trainer.step(&rt, &state, sched.lr(step), step).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {step}");
        state = s;
    }
    let last = trainer.eval(&rt, &state).unwrap();
    assert!(
        last < first - 0.3,
        "training did not reduce eval loss: {first} -> {last}"
    );
}

#[test]
fn bert_and_vit_train_steps_run() {
    let rt = rt();
    for name in ["bert_nano", "vit_nano"] {
        let cfg = rt.cfg(name).unwrap().clone();
        let mut state = init_state(&rt, &cfg, 1).unwrap();
        let mut trainer = Trainer::new(&rt, name, 0, 3, 1).unwrap();
        let e0 = trainer.eval(&rt, &state).unwrap();
        for step in 1..=20 {
            let (s, loss) = trainer.step(&rt, &state, 1e-3, step).unwrap();
            assert!(loss.is_finite(), "{name} loss not finite");
            state = s;
        }
        let e1 = trainer.eval(&rt, &state).unwrap();
        assert!(e1 < e0 + 0.1, "{name} loss exploded: {e0} -> {e1}");
    }
}

#[test]
fn pallas_train_step_matches_ref_path() {
    // The gpt_nano Pallas-kernel build must produce (near-)identical losses
    // to the ref-path build for the same seeds. On the reference backend
    // both names dispatch to the same host kernels, so this also proves the
    // artifact alias resolves.
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();

    let run = |artifact: &str| -> Vec<f32> {
        let mut state = init_state(&rt, &cfg, 9).unwrap();
        let mut tr =
            Trainer::with_artifact(&rt, "gpt_nano", artifact, 0, 5, 1).unwrap();
        let mut losses = Vec::new();
        for step in 1..=5 {
            let (s, loss) = tr.step(&rt, &state, 1e-3, step).unwrap();
            losses.push(loss);
            state = s;
        }
        losses
    };
    let ref_losses = run("train_step__gpt_nano");
    let pal_losses = run("train_step_pallas__gpt_nano");
    for (a, b) in ref_losses.iter().zip(&pal_losses) {
        assert!((a - b).abs() < 1e-4, "pallas {b} vs ref {a}");
    }
}

#[test]
fn coalesce_refine_roundtrip_preserves_function() {
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let state = init_state(&rt, &cfg, 3).unwrap();
    let trainer = Trainer::new(&rt, "gpt_nano", 0, 1, 2).unwrap();
    let loss_orig = trainer.eval(&rt, &state).unwrap();

    let small = operators::coalesce(&rt, "gpt_nano", "gpt_nano_lv2", &state).unwrap();
    assert_eq!(small.n_params, rt.cfg("gpt_nano_lv2").unwrap().n_params);
    // de-coalesce with alpha=1 (pure growth): function is approximately
    // preserved through the C → D round trip (paper Eq. 8–11)
    let back = operators::refine(&rt, "gpt_nano", "gpt_nano_lv2", &state, &small, 1.0, false)
        .unwrap();
    let loss_back = trainer.eval(&rt, &back).unwrap();
    assert!(
        (loss_back - loss_orig).abs() < 0.25,
        "round trip broke the function: {loss_orig} -> {loss_back}"
    );

    // alpha=0 must return exactly the original theta
    let same = operators::refine(&rt, "gpt_nano", "gpt_nano_lv2", &state, &small, 0.0, false)
        .unwrap();
    let a = state.theta(&rt).unwrap();
    let b = same.theta(&rt).unwrap();
    let max_diff = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-6, "alpha=0 changed theta by {max_diff}");
}

#[test]
fn interp_artifact_is_affine() {
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let a = init_state(&rt, &cfg, 1).unwrap();
    let b = init_state(&rt, &cfg, 2).unwrap();
    let mid = operators::interp_states(&rt, "gpt_nano", &a, &b, 0.5).unwrap();
    let (ha, hb, hm) = (
        a.to_host(&rt).unwrap(),
        b.to_host(&rt).unwrap(),
        mid.to_host(&rt).unwrap(),
    );
    for i in (0..ha.len()).step_by(997) {
        let want = 0.5 * ha[i] + 0.5 * hb[i];
        assert!((hm[i] - want).abs() < 1e-6);
    }
}

#[test]
fn loss_scalar_read_matches_full_read() {
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let state = init_state(&rt, &cfg, 4).unwrap();
    let mut trainer = Trainer::new(&rt, "gpt_nano", 0, 11, 1).unwrap();
    let (s, loss) = trainer.step(&rt, &state, 1e-3, 1).unwrap();
    let full = s.to_host(&rt).unwrap();
    assert_eq!(loss, full[0], "partial read != full read");
}

// ---------------------------------------------------------------------------
// Device-requiring coverage (needs `--features pjrt` + `make artifacts` +
// a real `xla` crate vendored in place of the stub)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_manifest_loads_when_artifacts_present() {
    let dir = std::env::var("ML_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let path = std::path::Path::new(&dir);
    if !path.join("manifest.json").exists() {
        eprintln!("skipping: no AOT artifacts at {dir}");
        return;
    }
    // the on-disk manifest must parse + validate regardless of device
    let m = multilevel::runtime::Manifest::load(path).unwrap();
    m.validate().unwrap();
    // a real PJRT device (not the API stub) additionally runs a train step
    if let Ok(rt) = Runtime::load(path) {
        let cfg = rt.cfg("gpt_nano").unwrap().clone();
        let state = init_state(&rt, &cfg, 1).unwrap();
        let mut tr = Trainer::new(&rt, "gpt_nano", 0, 2, 1).unwrap();
        let (_, loss) = tr.step(&rt, &state, 1e-3, 1).unwrap();
        assert!(loss.is_finite());
    } else {
        eprintln!("skipping device execution: PJRT client unavailable (xla stub)");
    }
}
