//! Property-based tests (via util::prop — proptest is unavailable offline)
//! on coordinator invariants: schedules, metrics, data streams, JSON, RNG.

use multilevel::coordinator::metrics::{savings_vs_scratch, Curve, Point};
use multilevel::coordinator::LrSchedule;
use multilevel::data::corpus::{Corpus, FIRST_WORD};
use multilevel::data::batcher::mask_mlm;
use multilevel::util::json::Json;
use multilevel::util::prop::{check, no_shrink};
use multilevel::util::rng::Rng;

#[test]
fn prop_lr_schedule_bounded_and_positive() {
    check(
        "lr in (0, peak]",
        1,
        300,
        |r| {
            let total = 10 + r.below(5000);
            let warmup = r.below(total / 2 + 1);
            let peak = 1e-5 + r.f64() as f32;
            (warmup, peak, total, 1 + r.below(total))
        },
        no_shrink,
        |&(warmup, peak, total, step)| {
            let s = LrSchedule::new(warmup, peak, total);
            let lr = s.lr(step);
            if lr > 0.0 && lr <= peak * 1.0001 {
                Ok(())
            } else {
                Err(format!("lr {lr} out of (0, {peak}]"))
            }
        },
    );
}

#[test]
fn prop_lr_peak_reached_at_warmup_end() {
    check(
        "lr(warmup) == peak",
        2,
        200,
        |r| (1 + r.below(100), 1e-4 + r.f32()),
        no_shrink,
        |&(warmup, peak)| {
            let s = LrSchedule::new(warmup, peak, warmup * 10 + 10);
            let lr = s.lr(warmup);
            if (lr - peak).abs() < peak * 1e-4 {
                Ok(())
            } else {
                Err(format!("lr(warmup)={lr} != peak={peak}"))
            }
        },
    );
}

fn synth_curve(rng: &mut Rng, cfg: &str) -> Curve {
    let mut c = Curve::new("synthetic");
    let n = 3 + rng.below(40);
    let mut flops = 0.0;
    let mut loss = 4.0 + rng.f32();
    for i in 0..n {
        flops += 1e8 * (1.0 + rng.f64());
        loss = (loss - 0.1 * rng.f32()).max(0.5);
        c.points.push(Point {
            phase: 0,
            config: cfg.into(),
            step: i + 1,
            flops,
            wall: flops / 1e9,
            train_loss: loss,
            eval_loss: if i % 2 == 0 { Some(loss) } else { None },
        });
    }
    c.total_flops = flops;
    c.total_wall = flops / 1e9;
    c
}

#[test]
fn prop_time_to_target_monotone_in_target() {
    // a looser target is never reached later
    check(
        "ttt monotone",
        3,
        300,
        |r| {
            let c = synth_curve(r, "m");
            let t1 = 0.5 + r.f32() * 4.0;
            let t2 = t1 + r.f32();
            (c, t1, t2)
        },
        no_shrink,
        |(c, t1, t2)| {
            let a = c.time_to_target("m", *t1); // tighter
            let b = c.time_to_target("m", *t2); // looser
            match (a, b) {
                (Some((fa, _)), Some((fb, _))) if fb > fa => {
                    Err(format!("looser target reached later: {fb} > {fa}"))
                }
                (Some(_), None) => Err("tight target reached but loose not".into()),
                _ => Ok(()),
            }
        },
    );
}

#[test]
fn prop_savings_identity_is_zero() {
    // comparing a run against itself gives ~0 savings and reached=true
    check(
        "self savings == 0",
        4,
        200,
        |r| synth_curve(r, "m"),
        no_shrink,
        |c| {
            let s = savings_vs_scratch(c, c, "m");
            if !s.reached {
                return Err("self comparison did not reach".into());
            }
            if s.flops.abs() < 0.6 {
                Ok(())
            } else {
                Err(format!("self saving {}", s.flops))
            }
        },
    );
}

#[test]
fn prop_corpus_tokens_in_vocab() {
    check(
        "corpus range",
        5,
        100,
        |r| (64 + r.below(1000), r.next_u64(), r.next_u64()),
        no_shrink,
        |&(vocab, domain, seed)| {
            let c = Corpus::new(vocab, domain);
            let seq = c.sequence(64, &mut Rng::new(seed));
            for &t in &seq[1..] {
                if t < FIRST_WORD || t as usize >= vocab {
                    return Err(format!("token {t} outside [2, {vocab})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mlm_masking_invariants() {
    check(
        "mlm invariants",
        6,
        200,
        |r| {
            let vocab = 32 + r.below(500);
            let seq = 4 + r.below(60);
            let rows = 1 + r.below(8);
            let seed = r.next_u64();
            (vocab, seq, rows, seed)
        },
        no_shrink,
        |&(vocab, seq, rows, seed)| {
            let c = Corpus::new(vocab, 0);
            let mut rng = Rng::new(seed);
            let mut tokens = Vec::new();
            for _ in 0..rows {
                tokens.extend(c.sequence(seq, &mut rng));
            }
            let (masked, labels) = mask_mlm(&tokens, vocab, seq, &mut rng);
            if masked.len() != tokens.len() || labels.len() != tokens.len() {
                return Err("length mismatch".into());
            }
            for r in 0..rows {
                let row = &labels[r * seq..(r + 1) * seq];
                if !row.iter().any(|&l| l >= 0) {
                    return Err(format!("row {r} has no masked position"));
                }
            }
            for i in 0..tokens.len() {
                if labels[i] >= 0 {
                    if labels[i] != tokens[i] {
                        return Err("label != original token".into());
                    }
                } else if masked[i] != tokens[i] {
                    return Err("unmasked position was altered".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_numbers_strings() {
    check(
        "json roundtrip",
        7,
        300,
        |r| {
            let n = (r.f64() - 0.5) * 1e6;
            let s: String = (0..r.below(20))
                .map(|_| char::from_u32(32 + r.below(90) as u32).unwrap())
                .collect();
            (n, s)
        },
        no_shrink,
        |(n, s)| {
            let src = multilevel::util::json::obj(vec![
                ("num", multilevel::util::json::num(*n)),
                ("str", multilevel::util::json::s(s)),
            ]);
            let back = Json::parse(&src.to_string()).map_err(|e| e.to_string())?;
            let got = back.get("num").as_f64().ok_or("missing num")?;
            if (got - n).abs() > n.abs() * 1e-9 + 1e-9 {
                return Err(format!("{got} != {n}"));
            }
            if back.get("str").as_str() != Some(s.as_str()) {
                return Err("string mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rng_below_uniformish() {
    check(
        "rng below spread",
        8,
        20,
        |r| (2 + r.below(50), r.next_u64()),
        no_shrink,
        |&(n, seed)| {
            let mut rng = Rng::new(seed);
            let mut counts = vec![0usize; n];
            let draws = n * 200;
            for _ in 0..draws {
                counts[rng.below(n)] += 1;
            }
            let expect = draws / n;
            for (i, &c) in counts.iter().enumerate() {
                if c < expect / 4 || c > expect * 4 {
                    return Err(format!("bucket {i}: {c} vs expected {expect}"));
                }
            }
            Ok(())
        },
    );
}
