//! Thread-count invariance: `PALLAS_REF_THREADS` (and the pool size in
//! general) must only change wall time — artifact results are required to
//! be bit-identical for 1, 2, and 8 threads.
//!
//! Tests serialize on a local mutex because the pool is process-global and
//! the test harness runs tests concurrently.

use std::sync::{Mutex, MutexGuard};

use multilevel::coordinator::{operators, Trainer};
use multilevel::runtime::reference::exec::{decode_step, prefill};
use multilevel::runtime::reference::simd;
use multilevel::runtime::{init_state, init_theta, Manifest, Runtime};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn train_steps_bit_identical_across_thread_counts() {
    let _g = lock();
    let before = threadpool::threads();
    let rt = Runtime::reference();
    // big enough to cross every parallel-dispatch threshold (d=96, T=256)
    let run = |threads: usize| {
        threadpool::set_threads(threads);
        let cfg = rt.cfg("gpt_base_sim").unwrap().clone();
        let mut state = init_state(&rt, &cfg, 11).unwrap();
        let mut tr = Trainer::new(&rt, "gpt_base_sim", 0, 5, 1).unwrap();
        for step in 1..=2 {
            let (s, loss) = tr.step(&rt, &state, 1e-3, step).unwrap();
            assert!(loss.is_finite());
            state = s;
        }
        state.to_host(&rt).unwrap()
    };
    let t1 = run(1);
    let t2 = run(2);
    let t8 = run(8);
    threadpool::set_threads(before);
    assert_eq!(bits(&t1), bits(&t2), "1 vs 2 threads diverged");
    assert_eq!(bits(&t1), bits(&t8), "1 vs 8 threads diverged");
}

#[test]
fn level_transition_operators_bit_identical_across_thread_counts() {
    let _g = lock();
    let before = threadpool::threads();
    let rt = Runtime::reference();
    let run = |threads: usize| {
        threadpool::set_threads(threads);
        let cfg = rt.cfg("bert_base_sim").unwrap().clone();
        let state = init_state(&rt, &cfg, 3).unwrap();
        let small =
            operators::coalesce(&rt, "bert_base_sim", "bert_base_sim_lv2", &state).unwrap();
        let back = operators::refine(
            &rt,
            "bert_base_sim",
            "bert_base_sim_lv2",
            &state,
            &small,
            0.3,
            false,
        )
        .unwrap();
        let mut out = small.to_host(&rt).unwrap();
        out.extend(back.to_host(&rt).unwrap());
        out
    };
    let t1 = run(1);
    let t2 = run(2);
    let t8 = run(8);
    threadpool::set_threads(before);
    assert_eq!(bits(&t1), bits(&t2), "1 vs 2 threads diverged");
    assert_eq!(bits(&t1), bits(&t8), "1 vs 8 threads diverged");
}

#[test]
fn device_info_reports_thread_count_and_block_size() {
    let _g = lock();
    let before = threadpool::threads();
    threadpool::set_threads(3);
    let rt = Runtime::reference();
    let info = rt.device_info();
    threadpool::set_threads(before);
    assert!(info.starts_with("reference-cpu"), "{info}");
    assert!(info.contains("threads=3"), "{info}");
    assert!(info.contains("gemm"), "{info}");
    assert!(info.contains("simd="), "{info}");
}

/// Decode replay must be bitwise stable per kernel tier: for a fixed
/// `PALLAS_REF_SIMD` selection, prefill + decode records are bit-identical
/// across repeats and across thread counts — on the scalar tier and on the
/// detected best tier.
#[test]
fn decode_replay_bit_identical_per_tier_across_threads() {
    let _g = lock();
    let before_threads = threadpool::threads();
    let before_tier = simd::tier();
    let m = Manifest::builtin();
    let cfg = m.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let corpus = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(19);
    let mut tokens = Vec::new();
    for _ in 0..cfg.batch {
        tokens.extend(corpus.sequence(cfg.seq_len, &mut rng));
    }
    let plen = (cfg.seq_len / 2).max(1);
    let lens = vec![plen as i32; cfg.batch];
    let next: Vec<i32> =
        (0..cfg.batch).map(|bi| tokens[bi * cfg.seq_len + plen - 1]).collect();
    let run = |threads: usize| {
        threadpool::set_threads(threads);
        let recs = prefill(&cfg, &theta, &tokens, &lens).unwrap();
        let stepped = decode_step(&cfg, &theta, &recs, &next, &lens).unwrap();
        (recs, stepped)
    };
    let mut tiers = vec![simd::Tier::Scalar];
    if simd::detected_best() != simd::Tier::Scalar {
        tiers.push(simd::detected_best());
    }
    for tier in tiers {
        simd::set_tier(tier).unwrap();
        let (r1, s1) = run(1);
        let (r1b, s1b) = run(1);
        let (r8, s8) = run(8);
        assert_eq!(bits(&r1), bits(&r1b), "{}: prefill replay diverged", tier.name());
        assert_eq!(bits(&s1), bits(&s1b), "{}: decode replay diverged", tier.name());
        assert_eq!(bits(&r1), bits(&r8), "{}: prefill 1 vs 8 threads diverged", tier.name());
        assert_eq!(bits(&s1), bits(&s8), "{}: decode 1 vs 8 threads diverged", tier.name());
    }
    simd::set_tier(before_tier).unwrap();
    threadpool::set_threads(before_threads);
}
