//! Continuous-batching serving contracts (`coordinator/serve.rs`).
//!
//! Pinned here:
//! * **Replay determinism** — the same arrival trace replays bit-identically
//!   (tokens, finish steps, completion order, rejections) across
//!   `PALLAS_REF_THREADS` ∈ {1, 2, 4} and `PALLAS_REPLICAS` ∈ {1, 2}:
//!   scheduling is a pure function of the trace, sampling is a pure
//!   function of (seed, request id).
//! * **Continuous batching** — requests join and leave the slot pool
//!   mid-decode: overlapping requests share decode sweeps, so the engine
//!   issues far fewer `decode_step` calls than tokens generated.
//! * **Admission control** — a full queue rejects fail-closed; a
//!   single-slot engine completes FIFO.
//! * **Reporting** — latency percentiles are ordered and throughput
//!   accounting matches the trace.
//!
//! Tests share the process-global thread pool, so they serialize on a
//! local mutex.

use std::sync::{Mutex, MutexGuard};

use multilevel::coordinator::{synthetic_trace, ServeEngine, ServeOpts, TrafficSpec};
use multilevel::runtime::{init_theta, Runtime};
use multilevel::util::threadpool;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The replay-relevant outcome of a run: everything except wall-clock
/// latencies, which are measured but excluded from the contract.
fn outcome(rep: &multilevel::coordinator::ServeReport) -> Vec<(usize, usize, Vec<i32>)> {
    let mut v: Vec<(usize, usize, Vec<i32>)> =
        rep.served.iter().map(|r| (r.id, r.finish_step, r.tokens.clone())).collect();
    v.push((usize::MAX, rep.steps, rep.rejected.iter().map(|&i| i as i32).collect()));
    v
}

#[test]
fn replayed_trace_is_bit_identical_across_threads_and_replicas() {
    let _g = lock();
    let before = threadpool::threads();
    let rt0 = Runtime::reference();
    let cfg = rt0.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let trace = synthetic_trace(&cfg, &TrafficSpec::quick(21, 10)).unwrap();
    let opts = ServeOpts {
        max_batch: 2, // smaller than the trace: slots churn mid-run
        max_queue: 10,
        temperature: 0.7, // per-request seeded streams, not just argmax
        seed: 9,
        ..ServeOpts::default()
    };
    let mut want = None;
    for threads in [1usize, 2, 4] {
        threadpool::set_threads(threads);
        for replicas in [1usize, 2] {
            let rt = if replicas == 1 { Runtime::reference() } else { Runtime::sharded(replicas) };
            let eng = ServeEngine::new(&rt, "gpt_nano", opts.clone()).unwrap();
            let rep = eng.run(&rt, &theta, &trace).unwrap();
            let got = outcome(&rep);
            match &want {
                None => want = Some(got),
                Some(w) => assert_eq!(
                    &got, w,
                    "serve replay diverged at {threads} threads, {replicas} replicas"
                ),
            }
        }
    }
    threadpool::set_threads(before);
}

#[test]
fn overlapping_requests_share_decode_sweeps() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    // a burst: everyone arrives at once, so the pool stays full and the
    // engine amortizes decode sweeps across slots
    let spec = TrafficSpec { mean_interarrival: 0.01, ..TrafficSpec::quick(33, 12) };
    let trace = synthetic_trace(&cfg, &spec).unwrap();
    let eng = ServeEngine::new(
        &rt,
        "gpt_nano",
        ServeOpts { max_queue: 12, ..ServeOpts::default() },
    )
    .unwrap();
    let rep = eng.run(&rt, &theta, &trace).unwrap();
    assert_eq!(rep.served.len(), trace.len(), "rejected: {:?}", rep.rejected);
    let total: usize = trace.iter().map(|r| r.max_new).sum();
    assert_eq!(rep.generated_tokens, total);
    // continuous batching: strictly fewer sweeps than decoded tokens
    // (equality would mean every request decoded alone)
    let decode_tokens = total - trace.len(); // first token of each comes from prefill
    if decode_tokens > 0 {
        assert!(
            rep.decode_calls < decode_tokens,
            "{} decode calls for {} decoded tokens — no batching happened",
            rep.decode_calls,
            decode_tokens
        );
    }
}

#[test]
fn single_slot_engine_completes_fifo_and_reuses_the_slot() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let trace = synthetic_trace(&cfg, &TrafficSpec::quick(4, 6)).unwrap();
    let eng = ServeEngine::new(
        &rt,
        "gpt_nano",
        ServeOpts { max_batch: 1, max_queue: 6, ..ServeOpts::default() },
    )
    .unwrap();
    let rep = eng.run(&rt, &theta, &trace).unwrap();
    assert!(rep.rejected.is_empty(), "queue sized for the trace");
    let ids: Vec<usize> = rep.served.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..trace.len()).collect::<Vec<_>>(),
               "one slot must serve strictly in arrival order");
    // one slot serving 6 requests is reuse by construction; each request's
    // budget must still be honored exactly
    for r in &rep.served {
        assert_eq!(r.tokens.len(), trace[r.id].max_new, "request {} budget", r.id);
    }
}

#[test]
fn report_latencies_and_throughput_are_consistent() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let trace = synthetic_trace(&cfg, &TrafficSpec::quick(8, 8)).unwrap();
    let eng = ServeEngine::new(
        &rt,
        "gpt_nano",
        ServeOpts { max_queue: 8, ..ServeOpts::default() },
    )
    .unwrap();
    let rep = eng.run(&rt, &theta, &trace).unwrap();
    assert!(rep.wall_secs > 0.0);
    assert!(rep.tokens_per_sec() > 0.0);
    assert!(rep.p50_ms() <= rep.p99_ms(), "percentiles out of order");
    let max_lat = rep.served.iter().map(|r| r.latency_secs).fold(0.0f64, f64::max);
    assert!(rep.p99_ms() <= max_lat * 1e3 + 1e-9, "p99 beyond the maximum latency");
    for r in &rep.served {
        assert!(r.latency_secs >= 0.0);
        assert!(r.finish_step >= r.arrival_step);
    }
}
