//! Coalesced-draft speculative decoding contracts (`coordinator/generate.rs`
//! and the `verify_step__*` artifacts).
//!
//! Pinned here:
//! * **Greedy equivalence** — speculative decoding emits tokens bitwise
//!   identical to plain greedy decoding for every `k`, on every tested
//!   config, across `PALLAS_REF_THREADS` ∈ {1, 2, 4}, `PALLAS_REPLICAS`
//!   ∈ {1, 2}, and every kernel tier the host can run. Speculation
//!   changes walltime, never output.
//! * **Verifier semantics** — one `verify_step` call scores exactly what
//!   `k + 1` sequential `decode_step`s would: logits block `i` matches
//!   the sequential chain after consuming candidates `0..i`, and the
//!   returned K/V cache matches the sequential cache.
//! * **Rollback** — after a partial acceptance, the adopted record
//!   (verifier logits at the acceptance point + its advanced cache, with
//!   stale rejected-candidate rows beyond it) continues the plain greedy
//!   chain exactly.
//! * **Fail closed** — non-causal configs, out-of-range `k`, missing
//!   draft geometries, non-greedy samplers, and prompts too long for a
//!   verify window are all errors, never silent fallbacks.
//!
//! Tests share the process-global thread pool and kernel tier, so they
//! serialize on a local mutex.

use std::sync::{Mutex, MutexGuard};

use multilevel::coordinator::{GenerateRequest, Generator, Sampler, SpecDecoder};
use multilevel::runtime::reference::simd;
use multilevel::runtime::registry::SPEC_K;
use multilevel::runtime::{init_theta, Arg, ModelCfg, Runtime};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn prompts(cfg: &ModelCfg, plen: usize, seed: u64) -> Vec<i32> {
    let c = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..cfg.batch {
        out.extend(c.sequence(plen, &mut rng));
    }
    out
}

fn plain_greedy(
    rt: &Runtime,
    config: &str,
    theta: &[f32],
    p: &[i32],
    plen: usize,
    gen: usize,
) -> Vec<Vec<i32>> {
    let g = Generator::new(rt, config).unwrap();
    g.generate(rt, theta, GenerateRequest::new(p, plen).max_new_tokens(gen))
        .unwrap()
        .tokens
}

fn spec_greedy(
    rt: &Runtime,
    config: &str,
    level: usize,
    k: usize,
    theta: &[f32],
    p: &[i32],
    plen: usize,
    gen: usize,
) -> Vec<Vec<i32>> {
    let dec = SpecDecoder::new(rt, config, level, k).unwrap();
    dec.generate(rt, theta, GenerateRequest::new(p, plen).max_new_tokens(gen))
        .unwrap()
        .tokens
}

#[test]
fn spec_is_bitwise_identical_to_plain_greedy_for_every_k() {
    let _g = lock();
    let rt = Runtime::reference();
    for config in ["gpt_nano", "gpt_base_sim"] {
        let cfg = rt.cfg(config).unwrap().clone();
        let theta = init_theta(&cfg, 11);
        let plen = (cfg.seq_len / 4).max(1);
        // run through the spec window AND into the plain tail
        let gen = cfg.seq_len - plen + 1;
        let p = prompts(&cfg, plen, 3);
        let want = plain_greedy(&rt, config, &theta, &p, plen, gen);
        for k in [1usize, 2, 4] {
            let got = spec_greedy(&rt, config, 2, k, &theta, &p, plen, gen);
            assert_eq!(
                got, want,
                "speculative decode (k={k}) diverged from plain greedy on {config}"
            );
        }
    }
}

#[test]
fn spec_stats_account_every_round() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 11);
    let (plen, gen) = (4usize, 10usize);
    let p = prompts(&cfg, plen, 3);
    let dec = SpecDecoder::new(&rt, "gpt_nano", 2, 4).unwrap();
    assert_eq!(dec.k(), 4);
    assert_eq!(dec.draft_cfg().name, "gpt_nano_lv2");
    let out = dec
        .generate(&rt, &theta, GenerateRequest::new(&p, plen).max_new_tokens(gen))
        .unwrap();
    let s = out.stats;
    assert!(s.verify_calls > 0, "no speculative round ran");
    assert!(s.drafted > 0, "k = 4 must draft");
    assert!(
        s.drafted <= s.verify_calls * (dec.k() as u64 - 1) * cfg.batch as u64,
        "drafted {} exceeds rounds {} x (k-1) x batch",
        s.drafted,
        s.verify_calls
    );
    assert!(s.accepted <= s.drafted, "accepted {} > drafted {}", s.accepted, s.drafted);
    let rate = s.acceptance_rate();
    assert!((0.0..=1.0).contains(&rate), "acceptance rate {rate}");
    // every emitted token is committed exactly once
    let total: usize = out.tokens.iter().map(Vec::len).sum();
    assert_eq!(total, gen * cfg.batch);
}

#[test]
fn spec_matches_plain_across_threads_replicas_and_tiers() {
    let _g = lock();
    let before_threads = threadpool::threads();
    let before_tier = simd::tier();
    let rt0 = Runtime::reference();
    let cfg = rt0.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 7);
    let (plen, gen) = (3usize, 9usize);
    let p = prompts(&cfg, plen, 5);
    let mut tiers = vec![simd::Tier::Scalar];
    if simd::detected_best() != simd::Tier::Scalar {
        tiers.push(simd::detected_best());
    }
    for tier in tiers {
        simd::set_tier(tier).unwrap();
        // tokens may differ between tiers (different fp paths); within a
        // tier they must be identical across threads and replicas, and
        // spec must match plain everywhere
        let mut want: Option<Vec<Vec<i32>>> = None;
        for threads in [1usize, 2, 4] {
            threadpool::set_threads(threads);
            for replicas in [1usize, 2] {
                let rt = if replicas == 1 {
                    Runtime::reference()
                } else {
                    Runtime::sharded(replicas)
                };
                let plain = plain_greedy(&rt, "gpt_nano", &theta, &p, plen, gen);
                let spec = spec_greedy(&rt, "gpt_nano", 2, 4, &theta, &p, plen, gen);
                assert_eq!(
                    spec, plain,
                    "spec != plain at tier {:?}, {threads} threads, {replicas} replicas",
                    tier
                );
                match &want {
                    None => want = Some(plain),
                    Some(w) => assert_eq!(
                        &plain, w,
                        "plain greedy diverged at tier {:?}, {threads} threads, \
                         {replicas} replicas",
                        tier
                    ),
                }
            }
        }
    }
    simd::set_tier(before_tier).unwrap();
    threadpool::set_threads(before_threads);
}

/// Prefill `cfg.batch` prompts and return the decode record.
fn prefill_recs(rt: &Runtime, config: &str, theta: &[f32], p: &[i32], plen: usize) -> Vec<f32> {
    let cfg = rt.cfg(config).unwrap().clone();
    let (b, s) = (cfg.batch, cfg.seq_len);
    let mut padded = vec![0i32; b * s];
    for bi in 0..b {
        padded[bi * s..bi * s + plen].copy_from_slice(&p[bi * plen..(bi + 1) * plen]);
    }
    let lens = vec![plen as i32; b];
    let exe = rt.exe(&format!("prefill__{config}")).unwrap();
    let out = rt
        .call(
            &exe,
            &[
                Arg::F32(theta, vec![theta.len()]),
                Arg::I32(&padded, vec![b, s]),
                Arg::I32(&lens, vec![b]),
            ],
        )
        .unwrap();
    rt.read_f32(&out).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn verify_step_matches_sequential_decode_steps() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let (b, v) = (cfg.batch, cfg.vocab);
    let rec = cfg.decode_rec_len();
    let theta = init_theta(&cfg, 13);
    let plen = 4usize;
    let p = prompts(&cfg, plen, 9);
    let recs = prefill_recs(&rt, "gpt_nano", &theta, &p, plen);

    // arbitrary candidates — the verifier's contract holds whatever the
    // draft proposed
    let cand: Vec<i32> = (0..b * SPEC_K).map(|i| ((i * 13 + 5) % v) as i32).collect();
    let lens = vec![plen as i32; b];
    let verify = rt.exe("verify_step__gpt_nano").unwrap();
    let vout = rt
        .call(
            &verify,
            &[
                Arg::F32(&theta, vec![theta.len()]),
                Arg::F32(&recs, vec![b, rec]),
                Arg::I32(&cand, vec![b, SPEC_K]),
                Arg::I32(&lens, vec![b]),
            ],
        )
        .unwrap();
    let vhost = rt.read_f32(&vout).unwrap();
    let vrec = (SPEC_K + 1) * v + cfg.kv_cache_len();
    assert_eq!(vhost.len(), b * vrec);

    // block 0 is a copy of the input logits
    for bi in 0..b {
        assert_eq!(
            &vhost[bi * vrec..bi * vrec + v],
            &recs[bi * rec..bi * rec + v],
            "request {bi}: block 0 must copy the input logits"
        );
    }
    // block i matches i sequential decode_steps consuming cand[0..i]
    let decode = rt.exe("decode_step__gpt_nano").unwrap();
    let mut seq = recs.clone();
    for i in 1..=SPEC_K {
        let toks: Vec<i32> = (0..b).map(|bi| cand[bi * SPEC_K + i - 1]).collect();
        let slens = vec![(plen + i - 1) as i32; b];
        let out = rt
            .call(
                &decode,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::F32(&seq, vec![b, rec]),
                    Arg::I32(&toks, vec![b]),
                    Arg::I32(&slens, vec![b]),
                ],
            )
            .unwrap();
        seq = rt.read_f32(&out).unwrap();
        for bi in 0..b {
            let d = max_abs_diff(
                &vhost[bi * vrec + i * v..bi * vrec + (i + 1) * v],
                &seq[bi * rec..bi * rec + v],
            );
            assert!(d <= 1e-5, "request {bi}: verify block {i} differs from the \
                     sequential chain by {d}");
        }
    }
    // the verifier's cache matches the sequential cache after all SPEC_K
    for bi in 0..b {
        let d = max_abs_diff(
            &vhost[bi * vrec + (SPEC_K + 1) * v..(bi + 1) * vrec],
            &seq[bi * rec + v..(bi + 1) * rec],
        );
        assert!(d <= 1e-5, "request {bi}: verify cache differs by {d}");
    }
}

#[test]
fn adopted_record_after_partial_acceptance_continues_the_chain() {
    let _g = lock();
    let rt = Runtime::reference();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let (b, v) = (cfg.batch, cfg.vocab);
    let rec = cfg.decode_rec_len();
    let theta = init_theta(&cfg, 17);
    let plen = 4usize;
    let p = prompts(&cfg, plen, 21);
    let recs = prefill_recs(&rt, "gpt_nano", &theta, &p, plen);

    // the true greedy chain c_0 .. c_5 via sequential decode
    let decode = rt.exe("decode_step__gpt_nano").unwrap();
    let argmax = |logits: &[f32]| {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0 as i32
    };
    let mut chain: Vec<Vec<i32>> = vec![Vec::new(); b]; // per request
    let mut seq = recs.clone();
    for i in 0..6 {
        let toks: Vec<i32> = (0..b)
            .map(|bi| {
                let c = argmax(&seq[bi * rec..bi * rec + v]);
                chain[bi].push(c);
                c
            })
            .collect();
        let lens = vec![(plen + i) as i32; b];
        let out = rt
            .call(
                &decode,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::F32(&seq, vec![b, rec]),
                    Arg::I32(&toks, vec![b]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        seq = rt.read_f32(&out).unwrap();
    }

    // candidates: the true chain except a deliberately wrong last slot
    // -> the acceptance rule stops at m = SPEC_K - 2
    let mut cand = vec![0i32; b * SPEC_K];
    for bi in 0..b {
        for j in 0..SPEC_K {
            cand[bi * SPEC_K + j] = chain[bi][j];
        }
        let last = bi * SPEC_K + SPEC_K - 1;
        cand[last] = (cand[last] + 1).rem_euclid(v as i32);
    }
    let verify = rt.exe("verify_step__gpt_nano").unwrap();
    let lens = vec![plen as i32; b];
    let vout = rt
        .call(
            &verify,
            &[
                Arg::F32(&theta, vec![theta.len()]),
                Arg::F32(&recs, vec![b, rec]),
                Arg::I32(&cand, vec![b, SPEC_K]),
                Arg::I32(&lens, vec![b]),
            ],
        )
        .unwrap();
    let vhost = rt.read_f32(&vout).unwrap();
    let vrec = (SPEC_K + 1) * v + cfg.kv_cache_len();

    // acceptance: blocks 1..SPEC_K-1 match the chain, the last does not
    let m = SPEC_K - 2;
    let mut adopted = vec![0.0f32; b * rec];
    for bi in 0..b {
        let vr = &vhost[bi * vrec..(bi + 1) * vrec];
        for j in 1..=m {
            assert_eq!(
                argmax(&vr[j * v..(j + 1) * v]),
                chain[bi][j],
                "request {bi}: block {j} must accept its candidate"
            );
        }
        assert_ne!(
            argmax(&vr[(m + 1) * v..(m + 2) * v]),
            cand[bi * SPEC_K + m + 1],
            "request {bi}: the corrupted candidate must be rejected"
        );
        // roll back to the acceptance point: logits block m+1, cache as
        // returned (rows past the acceptance hold the rejected token)
        adopted[bi * rec..bi * rec + v].copy_from_slice(&vr[(m + 1) * v..(m + 2) * v]);
        adopted[bi * rec + v..(bi + 1) * rec].copy_from_slice(&vr[(SPEC_K + 1) * v..]);
        assert_eq!(argmax(&adopted[bi * rec..bi * rec + v]), chain[bi][m + 1],
                   "request {bi}: adopted logits must continue the chain");
    }
    // continue decoding from the adopted record: the stale row is
    // rewritten before it is read, so the chain stays exact
    let mut cur = adopted;
    for i in (m + 1)..5 {
        let toks: Vec<i32> = (0..b).map(|bi| chain[bi][i]).collect();
        let lens = vec![(plen + i) as i32; b];
        let out = rt
            .call(
                &decode,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::F32(&cur, vec![b, rec]),
                    Arg::I32(&toks, vec![b]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        cur = rt.read_f32(&out).unwrap();
        for bi in 0..b {
            assert_eq!(
                argmax(&cur[bi * rec..bi * rec + v]),
                chain[bi][i + 1],
                "request {bi}: chain diverged at position {} after rollback",
                plen + i + 1
            );
        }
    }
}

#[test]
fn spec_fails_closed() {
    let _g = lock();
    let rt = Runtime::reference();
    // non-causal configs have no decode path at all
    let err = SpecDecoder::new(&rt, "bert_nano", 2, 2).unwrap_err().to_string();
    assert!(err.contains("causal"), "{err}");
    // k outside 1..=SPEC_K
    let err = SpecDecoder::new(&rt, "gpt_nano", 2, 0).unwrap_err().to_string();
    assert!(err.contains("--spec-k"), "{err}");
    let err = SpecDecoder::new(&rt, "gpt_nano", 2, SPEC_K + 1).unwrap_err().to_string();
    assert!(err.contains("--spec-k"), "{err}");
    // level 1 is the full model; level 3 has no coalesced geometry
    let err = SpecDecoder::new(&rt, "gpt_nano", 1, 2).unwrap_err().to_string();
    assert!(err.contains("--spec-draft"), "{err}");
    let err = SpecDecoder::new(&rt, "gpt_nano", 3, 2).unwrap_err().to_string();
    assert!(err.contains("level-3"), "{err}");

    let dec = SpecDecoder::new(&rt, "gpt_nano", 2, 4).unwrap();
    let cfg = dec.cfg().clone();
    let theta = init_theta(&cfg, 3);
    // non-greedy sampling breaks the equivalence contract
    let p = prompts(&cfg, 4, 1);
    let err = dec
        .generate(
            &rt,
            &theta,
            GenerateRequest::new(&p, 4)
                .max_new_tokens(2)
                .sampler(Sampler::temperature(0.8, 7).unwrap()),
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("greedy"), "{err}");
    // a prompt too long for even one verify window
    let plen = cfg.seq_len - SPEC_K + 1;
    let p = prompts(&cfg, plen, 1);
    let err = dec
        .generate(&rt, &theta, GenerateRequest::new(&p, plen).max_new_tokens(2))
        .unwrap_err()
        .to_string();
    assert!(err.contains("verify window"), "{err}");
}
