//! Kernel-tier contracts across the whole model: the fused attention
//! (inference) forward must be bit-identical to the training forward on
//! every tier, tier selection must round-trip through `device_info`, an
//! unsupported tier must fail closed, and scalar-vs-vector outputs agree
//! at tolerance.
//!
//! Tests serialize on a local mutex because the selected tier is
//! process-global and the harness runs tests concurrently.

use std::sync::{Mutex, MutexGuard};

use multilevel::runtime::reference::exec::{eval_loss, loss_and_grad, BatchRef};
use multilevel::runtime::reference::simd;
use multilevel::runtime::{init_theta, Manifest, ModelCfg, Runtime};
use multilevel::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg(name: &str) -> ModelCfg {
    Manifest::builtin().cfg(name).unwrap().clone()
}

fn toks(cfg: &ModelCfg, seed: u64) -> Vec<i32> {
    let c = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..cfg.batch {
        out.extend(c.sequence(cfg.seq_len, &mut rng));
    }
    out
}

/// MLM-style labels for the BERT batch: every third position is a loss
/// target, the rest are ignored (-1).
fn labels(tokens: &[i32]) -> Vec<i32> {
    tokens
        .iter()
        .enumerate()
        .map(|(i, &t)| if i % 3 == 0 { t } else { -1 })
        .collect()
}

/// The tiers to exercise on this host: scalar always, plus the detected
/// best vector tier when there is one.
fn tiers() -> Vec<simd::Tier> {
    let mut ts = vec![simd::Tier::Scalar];
    if simd::detected_best() != simd::Tier::Scalar {
        ts.push(simd::detected_best());
    }
    ts
}

#[test]
fn set_tier_round_trips_through_device_info() {
    let _g = lock();
    let before = simd::tier();
    for t in tiers() {
        simd::set_tier(t).unwrap();
        assert_eq!(simd::tier(), t);
        let info = Runtime::reference().device_info();
        assert!(info.contains(&format!("simd={}", t.name())), "{info}");
    }
    simd::set_tier(before).unwrap();
}

#[test]
fn unsupported_tier_fails_closed() {
    let _g = lock();
    let before = simd::tier();
    // AVX2 and NEON can never both be supported on one host.
    let bad = [simd::Tier::Avx2, simd::Tier::Neon]
        .into_iter()
        .find(|&t| !simd::supported(t))
        .expect("one vector tier is always foreign to the host");
    let err = simd::set_tier(bad).unwrap_err();
    assert!(err.contains("not supported"), "{err}");
    assert_eq!(simd::tier(), before, "a rejected set_tier must not change the tier");
}

/// The full-model fused/unfused parity check: `eval_loss` runs the fused
/// attention forward (no `[S,S]` probability tensor), `loss_and_grad` runs
/// the training forward that materializes it — the loss must agree bitwise
/// on every tier, for both attention masks.
#[test]
fn fused_eval_loss_matches_training_forward_bitwise() {
    let _g = lock();
    let before = simd::tier();
    for t in tiers() {
        simd::set_tier(t).unwrap();
        for name in ["gpt_nano", "bert_nano"] {
            let c = cfg(name);
            let theta = init_theta(&c, 23);
            let tokens = toks(&c, 29);
            let lab = labels(&tokens);
            let batch = if name.starts_with("gpt") {
                BatchRef::Gpt { tokens: &tokens }
            } else {
                BatchRef::Bert { tokens: &tokens, labels: &lab }
            };
            let fused = eval_loss(&c, &theta, &batch).unwrap();
            let (unfused, _) = loss_and_grad(&c, &theta, &batch).unwrap();
            assert_eq!(
                fused.to_bits(),
                unfused.to_bits(),
                "{name} on {}: fused {fused} vs unfused {unfused}",
                t.name()
            );
        }
    }
    simd::set_tier(before).unwrap();
}

/// Cross-tier outputs only need tolerance equality (the FMA reductions
/// reassociate) — pin the scalar and best-tier losses close together.
#[test]
fn scalar_and_vector_tier_losses_agree_at_tolerance() {
    let _g = lock();
    let best = simd::detected_best();
    if best == simd::Tier::Scalar {
        return; // nothing to compare on a scalar-only host
    }
    let before = simd::tier();
    let c = cfg("gpt_nano");
    let theta = init_theta(&c, 31);
    let tokens = toks(&c, 37);
    let batch = BatchRef::Gpt { tokens: &tokens };
    simd::set_tier(simd::Tier::Scalar).unwrap();
    let scalar = eval_loss(&c, &theta, &batch).unwrap();
    simd::set_tier(best).unwrap();
    let vector = eval_loss(&c, &theta, &batch).unwrap();
    simd::set_tier(before).unwrap();
    let tol = 1e-3 * (1.0 + scalar.abs());
    assert!(
        (scalar - vector).abs() < tol,
        "scalar {scalar} vs {} {vector} differ beyond {tol}",
        best.name()
    );
}
