//! Integration tests over the experiment harness: method programs compose,
//! curves have the right structure, checkpoint/resume works end to end,
//! fine-tuning probes learn. All of it runs on the pure-Rust
//! `ReferenceBackend` — no XLA device or AOT artifacts required.

use multilevel::coordinator::finetune::finetune_once;
use multilevel::coordinator::{Harness, Method, RunOpts};
use multilevel::runtime::{init_state, load_checkpoint, save_checkpoint, state_from_theta,
                          Runtime};

fn rt() -> Runtime {
    Runtime::reference()
}

fn quick_opts(base: &str, steps: usize) -> RunOpts {
    let mut o = RunOpts::quick(base, steps);
    o.eval_every = 10;
    o.val_batches = 2;
    o.budget_mult = 1.0;
    o
}

#[test]
fn vcycle_curve_has_three_phases_and_monotone_cost() {
    let rt = rt();
    let h = Harness::new(&rt, quick_opts("gpt_nano", 60));
    let curve = h.run_method(&Method::VCycle { levels: 2, fit: false }, None).unwrap();
    let phases: std::collections::BTreeSet<usize> =
        curve.points.iter().map(|p| p.phase).collect();
    assert!(phases.len() >= 3, "expected >=3 phases, got {phases:?}");
    // cumulative cost strictly increases
    for w in curve.points.windows(2) {
        assert!(w[1].flops > w[0].flops);
        assert!(w[1].wall >= w[0].wall);
    }
    // middle phase runs the coalesced config
    let mid = curve.points.iter().find(|p| p.phase == 2).unwrap();
    assert_eq!(mid.config, "gpt_nano_lv2");
    // final phase is the base config again
    assert_eq!(curve.points.last().unwrap().config, "gpt_nano");
}

#[test]
fn vcycle_small_phase_is_cheaper_per_step() {
    let rt = rt();
    let h = Harness::new(&rt, quick_opts("gpt_nano", 60));
    let curve = h.run_method(&Method::VCycle { levels: 2, fit: false }, None).unwrap();
    let df = |phase: usize| {
        let pts: Vec<_> = curve.points.iter().filter(|p| p.phase == phase).collect();
        (pts.last().unwrap().flops - pts[0].flops) / pts.len().max(1) as f64
    };
    assert!(df(2) < df(3) * 0.5, "small phase not cheaper: {} vs {}", df(2), df(3));
}

#[test]
fn every_method_program_runs_on_nano() {
    let rt = rt();
    let h = Harness::new(&rt, quick_opts("gpt_nano", 30));
    for m in [
        Method::Scratch,
        Method::StackBert,
        Method::Bert2Bert,
        Method::LiGO { fit: false },
        Method::NetExpansion,
        Method::DecoalescedOnly,
        Method::VCycleRandomSmall,
        Method::VCycle { levels: 2, fit: false },
    ] {
        let curve = h.run_method(&m, None).unwrap_or_else(|e| panic!("{m:?}: {e}"));
        assert!(curve.total_flops > 0.0, "{m:?} recorded no flops");
        assert!(
            curve.points.iter().all(|p| p.train_loss.is_finite()),
            "{m:?} produced non-finite losses"
        );
    }
}

#[test]
fn stop_target_early_stops() {
    let rt = rt();
    let h = Harness::new(&rt, quick_opts("gpt_nano", 80));
    // a trivially reachable target must cut the run short
    let full = h.run_method(&Method::Scratch, None).unwrap();
    let stopped = h.run_method(&Method::Scratch, Some(10.0)).unwrap();
    assert!(stopped.points.len() < full.points.len());
}

#[test]
fn checkpoint_resume_roundtrip_through_device() {
    let rt = rt();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let state = init_state(&rt, &cfg, 99).unwrap();
    let theta = state.theta(&rt).unwrap();
    let dir = std::env::temp_dir().join(format!("ml_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.ckpt");
    save_checkpoint(&path, &cfg, &theta).unwrap();
    let theta2 = load_checkpoint(&path, &cfg).unwrap();
    let resumed = state_from_theta(&rt, &cfg, &theta2).unwrap();
    assert_eq!(resumed.theta(&rt).unwrap(), theta);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn finetune_probe_beats_chance() {
    let rt = rt();
    let cfg = rt.cfg("bert_nano").unwrap().clone();
    // even an untrained backbone should learn an easy 4-way marker task well
    // above chance when fine-tuned end to end
    let theta = multilevel::runtime::init_theta(&cfg, 7);
    let acc = finetune_once(&rt, "bert_nano", &theta, 0, 1, 150, 5e-3).unwrap();
    assert!(acc > 32.0, "probe accuracy {acc}% not above 25% chance");
}

#[test]
fn distinct_seeds_give_distinct_runs() {
    let rt = rt();
    let mut o1 = quick_opts("gpt_nano", 20);
    let mut o2 = quick_opts("gpt_nano", 20);
    o1.seed = 1;
    o2.seed = 2;
    let c1 = Harness::new(&rt, o1).run_method(&Method::Scratch, None).unwrap();
    let c2 = Harness::new(&rt, o2).run_method(&Method::Scratch, None).unwrap();
    assert_ne!(
        c1.points.last().unwrap().train_loss,
        c2.points.last().unwrap().train_loss
    );
}

#[test]
fn same_seed_reproduces_exactly() {
    let rt = rt();
    let o = quick_opts("gpt_nano", 20);
    let c1 = Harness::new(&rt, o.clone()).run_method(&Method::Scratch, None).unwrap();
    let c2 = Harness::new(&rt, o).run_method(&Method::Scratch, None).unwrap();
    let l1: Vec<f32> = c1.points.iter().map(|p| p.train_loss).collect();
    let l2: Vec<f32> = c2.points.iter().map(|p| p.train_loss).collect();
    assert_eq!(l1, l2, "training is not deterministic under a fixed seed");
}

#[test]
fn wcycle_runs_and_revisits_coarse_level() {
    let rt = rt();
    let h = Harness::new(&rt, quick_opts("gpt_nano", 40));
    let curve = h.run_method(&Method::WCycle { levels: 2 }, None).unwrap();
    // W shape: two distinct coarse phases on the lv2 config
    let coarse_phases: std::collections::BTreeSet<usize> = curve
        .points
        .iter()
        .filter(|p| p.config == "gpt_nano_lv2")
        .map(|p| p.phase)
        .collect();
    assert!(coarse_phases.len() >= 2, "W-cycle visited coarse level once: {coarse_phases:?}");
    assert!(curve.points.iter().all(|p| p.train_loss.is_finite()));
    assert_eq!(curve.points.last().unwrap().config, "gpt_nano");
}
