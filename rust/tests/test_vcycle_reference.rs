//! End-to-end V-cycle coverage on the [`ReferenceBackend`]: a 2-level
//! BERT-tiny cycle (coalesce → train small → refine → train big) asserting
//! that level transitions preserve shapes/param counts, that `refine(α=1)`
//! reproduces pure de-coalescing exactly, and that the savings machinery
//! runs end to end on plain CPU.
//!
//! [`ReferenceBackend`]: multilevel::runtime::ReferenceBackend

use multilevel::coordinator::{operators, savings_vs_scratch, Harness, Method, RunOpts};
use multilevel::runtime::{init_state, Runtime};

fn opts(base: &str, steps: usize) -> RunOpts {
    let mut o = RunOpts::quick(base, steps);
    o.alpha = 0.5; // paper: α = 0.5 for BERT
    o.eval_every = 10;
    o.val_batches = 2;
    o.budget_mult = 1.0;
    o
}

#[test]
fn bert_tiny_two_level_vcycle_end_to_end() {
    let rt = Runtime::reference();
    let base = "bert_nano";
    let small = "bert_nano_lv2";
    let h = Harness::new(&rt, opts(base, 40));
    let curve = h.run_method(&Method::VCycle { levels: 2, fit: false }, None).unwrap();

    // three phases: warmup on base, coarse phase on lv2, final on base
    let phases: std::collections::BTreeSet<usize> =
        curve.points.iter().map(|p| p.phase).collect();
    assert!(phases.len() >= 3, "expected >= 3 phases, got {phases:?}");
    let mid = curve.points.iter().find(|p| p.phase == 2).unwrap();
    assert_eq!(mid.config, small);
    assert_eq!(curve.points.last().unwrap().config, base);
    assert!(curve.points.iter().all(|p| p.train_loss.is_finite()));
    // coarse steps are cheaper (fewer params/FLOPs per step)
    let df = |phase: usize| {
        let pts: Vec<_> = curve.points.iter().filter(|p| p.phase == phase).collect();
        (pts.last().unwrap().flops - pts[0].flops) / pts.len().max(1) as f64
    };
    assert!(df(2) < df(3), "coarse phase not cheaper: {} vs {}", df(2), df(3));
}

#[test]
fn coalesce_train_refine_preserves_shapes_and_counts() {
    let rt = Runtime::reference();
    let base_cfg = rt.cfg("bert_nano").unwrap().clone();
    let small_cfg = rt.cfg("bert_nano_lv2").unwrap().clone();
    let state = init_state(&rt, &base_cfg, 11).unwrap();

    let down = operators::coalesce(&rt, "bert_nano", "bert_nano_lv2", &state).unwrap();
    assert_eq!(down.n_params, small_cfg.n_params);
    let host = down.to_host(&rt).unwrap();
    assert_eq!(host.len(), 3 * small_cfg.n_params + 1);
    // Adam moments re-initialize at the transition (App. C)
    assert!(host[1 + small_cfg.n_params..].iter().all(|&v| v == 0.0));

    // train the coarse model a few steps, then come back up
    let mut tr = multilevel::coordinator::Trainer::new(&rt, "bert_nano_lv2", 0, 5, 1).unwrap();
    let mut coarse = down;
    for step in 1..=5 {
        let (s, loss) = tr.step(&rt, &coarse, 1e-3, step).unwrap();
        assert!(loss.is_finite());
        coarse = s;
    }
    let up = operators::refine(&rt, "bert_nano", "bert_nano_lv2", &state, &coarse, 0.5, false)
        .unwrap();
    assert_eq!(up.n_params, base_cfg.n_params);
    assert_eq!(up.to_host(&rt).unwrap().len(), 3 * base_cfg.n_params + 1);
}

#[test]
fn refine_alpha1_reproduces_decoalescing_exactly() {
    // With α = 1 the interpolation keeps none of the big model: the result
    // must be the pure de-coalescing of the small state, independent of
    // which big state is passed in (Algorithms 3+4).
    let rt = Runtime::reference();
    let base_cfg = rt.cfg("bert_nano").unwrap().clone();
    let small = init_state(&rt, rt.cfg("bert_nano_lv2").unwrap(), 3).unwrap();
    let big_a = init_state(&rt, &base_cfg, 1).unwrap();
    let big_b = init_state(&rt, &base_cfg, 2).unwrap();
    let up_a =
        operators::refine(&rt, "bert_nano", "bert_nano_lv2", &big_a, &small, 1.0, false).unwrap();
    let up_b =
        operators::refine(&rt, "bert_nano", "bert_nano_lv2", &big_b, &small, 1.0, false).unwrap();
    assert_eq!(
        up_a.theta(&rt).unwrap(),
        up_b.theta(&rt).unwrap(),
        "refine(α=1) depends on the big state — not pure de-coalescing"
    );
}

#[test]
fn savings_vs_scratch_runs_on_reference_backend() {
    let rt = Runtime::reference();
    let h = Harness::new(&rt, opts("bert_nano", 30));
    let scratch = h.run_method(&Method::Scratch, None).unwrap();
    let vcycle = h
        .run_method(&Method::VCycle { levels: 2, fit: false },
                    scratch.final_eval("bert_nano", 3))
        .unwrap();
    let s = savings_vs_scratch(&scratch, &vcycle, "bert_nano");
    assert!(s.target.is_finite());
    assert!(s.flops.is_finite() && s.wall.is_finite());
}
