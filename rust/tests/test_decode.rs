//! Decode-parity suite for the KV-cache serving path.
//!
//! Contracts pinned here:
//! * **Forward parity** — prefill + N `decode_step`s produce logits (and
//!   cache rows) tolerance-equal to a full-sequence forward at every
//!   generated length, for gpt_nano and gpt_base_sim.
//! * **Thread determinism** — the whole decode chain is bit-identical for
//!   `PALLAS_REF_THREADS` ∈ {1, 2, 4}.
//! * **Zero allocation** — steady-state `decode_step_into` performs zero
//!   heap allocations (counting global allocator, pool pinned to 1 thread
//!   like `test_workspace.rs`).
//! * **Sharded decode** — a batch of requests split across replicas
//!   concatenates to records bit-identical to replica-0 serial decode,
//!   with every request at a *uniform* depth and at *ragged* per-request
//!   depths (the `lens [B]` vector both artifacts now carry).
//! * **Causal-only** — BERT configs are rejected with a clear error at
//!   every layer (manifest validation, backend prepare, kernels).
//!
//! Tests share the process-global thread pool and one allocation counter,
//! so they serialize on a local mutex.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use multilevel::runtime::reference::exec::{decode_step, decode_step_into, prefill, Workspace};
use multilevel::runtime::{
    init_theta, Arg, Backend, Manifest, ModelCfg, ReferenceBackend, Runtime,
};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Uniform length vector — every request at the same depth (the
/// pre-ragged single-`len` call shape).
fn uni(cfg: &ModelCfg, len: usize) -> Vec<i32> {
    vec![len as i32; cfg.batch]
}

/// Ragged per-request depths covering 1..seq_len, no two alike when the
/// batch allows it.
fn ragged(cfg: &ModelCfg) -> Vec<i32> {
    (0..cfg.batch).map(|bi| (1 + (bi * 3) % (cfg.seq_len - 1)) as i32).collect()
}

fn setup(name: &str) -> (ModelCfg, Vec<f32>, Vec<i32>) {
    let m = Manifest::builtin();
    let cfg = m.cfg(name).unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let c = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(11);
    let mut toks = Vec::new();
    for _ in 0..cfg.batch {
        toks.extend(c.sequence(cfg.seq_len, &mut rng));
    }
    (cfg, theta, toks)
}

/// The incremental chain's records at every length `p0+1 ..= max_len`,
/// starting from a prefill of `p0` prompt tokens and feeding the original
/// sequence's tokens back in.
fn decode_chain(
    cfg: &ModelCfg,
    theta: &[f32],
    toks: &[i32],
    p0: usize,
    max_len: usize,
) -> Vec<Vec<f32>> {
    let s = cfg.seq_len;
    let mut recs = prefill(cfg, theta, toks, &uni(cfg, p0)).unwrap();
    let mut chain = Vec::new();
    for pos in p0..max_len {
        let next: Vec<i32> = (0..cfg.batch).map(|bi| toks[bi * s + pos]).collect();
        recs = decode_step(cfg, theta, &recs, &next, &uni(cfg, pos)).unwrap();
        chain.push(recs.clone());
    }
    chain
}

#[test]
fn incremental_decode_matches_full_forward_at_every_length() {
    let _g = lock();
    for name in ["gpt_nano", "gpt_base_sim"] {
        let (cfg, theta, toks) = setup(name);
        let s = cfg.seq_len;
        let rec = cfg.decode_rec_len();
        let p0 = 2usize;
        let chain = decode_chain(&cfg, &theta, &toks, p0, s);
        for (i, got) in chain.iter().enumerate() {
            // the oracle: a fresh full-sequence causal forward at this
            // length (prefill *is* the batched forward — backbone_fwd —
            // emitting last-position logits and all K/V rows)
            let want = prefill(&cfg, &theta, &toks, &uni(&cfg, p0 + i + 1)).unwrap();
            assert_eq!(got.len(), cfg.batch * rec);
            let mut max = 0.0f32;
            for j in 0..got.len() {
                max = max.max((got[j] - want[j]).abs());
            }
            assert!(
                max < 2e-4,
                "{name}: incremental records at length {} deviate from the \
                 full forward by {max}",
                p0 + i + 1
            );
        }
    }
}

#[test]
fn decode_chain_is_bit_identical_across_thread_counts() {
    let _g = lock();
    let before = threadpool::threads();
    let (cfg, theta, toks) = setup("gpt_base_sim");
    let mut want: Option<Vec<Vec<u32>>> = None;
    for threads in [1usize, 2, 4] {
        threadpool::set_threads(threads);
        let chain = decode_chain(&cfg, &theta, &toks, 3, cfg.seq_len.min(3 + 6));
        let got: Vec<Vec<u32>> = chain.iter().map(|r| bits(r)).collect();
        match &want {
            None => want = Some(got),
            Some(w) => assert_eq!(
                &got, w,
                "decode chain changed bits at {threads} kernel threads"
            ),
        }
    }
    threadpool::set_threads(before);
}

#[test]
fn ragged_decode_is_bit_identical_across_thread_counts() {
    let _g = lock();
    let before = threadpool::threads();
    let (cfg, theta, toks) = setup("gpt_base_sim");
    let lens = ragged(&cfg);
    let next: Vec<i32> =
        (0..cfg.batch).map(|bi| toks[bi * cfg.seq_len + lens[bi] as usize]).collect();
    let mut want: Option<(Vec<u32>, Vec<u32>)> = None;
    for threads in [1usize, 2, 4] {
        threadpool::set_threads(threads);
        let recs = prefill(&cfg, &theta, &toks, &lens).unwrap();
        let stepped = decode_step(&cfg, &theta, &recs, &next, &lens).unwrap();
        let got = (bits(&recs), bits(&stepped));
        match &want {
            None => want = Some(got),
            Some(w) => assert_eq!(
                &got, w,
                "ragged prefill/decode changed bits at {threads} kernel threads"
            ),
        }
    }
    threadpool::set_threads(before);
}

#[test]
fn steady_state_decode_step_performs_zero_heap_allocations() {
    let _g = lock();
    let before_threads = threadpool::threads();
    threadpool::set_threads(1);

    let (cfg, theta, toks) = setup("gpt_nano");
    let plen = cfg.seq_len / 2;
    let lens = uni(&cfg, plen);
    let mut ws = Workspace::new();
    let mut cur = Vec::new();
    multilevel::runtime::reference::exec::prefill_into(
        &cfg, &theta, &toks, &lens, &mut ws, &mut cur,
    )
    .unwrap();
    let next: Vec<i32> = (0..cfg.batch).map(|bi| toks[bi * cfg.seq_len + plen]).collect();
    let mut out = Vec::new();
    // warm-up: settle the arena pools and the ping-pong record buffers
    for _ in 0..3 {
        decode_step_into(&cfg, &theta, &cur, &next, &lens, &mut ws, &mut out).unwrap();
        std::mem::swap(&mut cur, &mut out);
    }
    let warm_misses = ws.alloc_misses();
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..5 {
        decode_step_into(&cfg, &theta, &cur, &next, &lens, &mut ws, &mut out).unwrap();
        std::mem::swap(&mut cur, &mut out);
    }
    let delta = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(delta, 0, "steady-state decode_step allocated {delta} times over 5 steps");
    assert_eq!(ws.alloc_misses(), warm_misses, "decode arena kept missing after warm-up");

    threadpool::set_threads(before_threads);
}

#[test]
fn sharded_request_decode_is_bit_identical_to_serial() {
    let _g = lock();
    let (cfg, theta, toks) = setup("gpt_base_sim");
    let (b, s) = (cfg.batch, cfg.seq_len);
    let plen = 4usize;
    let lens = uni(&cfg, plen);

    let run = |rt: &Runtime| -> (Vec<f32>, Vec<f32>) {
        let pf = rt.exe("prefill__gpt_base_sim").unwrap();
        let dc = rt.exe("decode_step__gpt_base_sim").unwrap();
        let recs = rt
            .call(
                &pf,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::I32(&toks, vec![b, s]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        let next: Vec<i32> = (0..b).map(|bi| toks[bi * s + plen]).collect();
        let stepped = rt
            .call(
                &dc,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::Buf(&recs),
                    Arg::I32(&next, vec![b]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        (rt.read_f32(&recs).unwrap(), rt.read_f32(&stepped).unwrap())
    };

    let serial = Runtime::reference();
    let (want_pre, want_step) = run(&serial);
    assert_eq!(want_pre.len(), b * cfg.decode_rec_len());
    // R = 3 exercises uneven request shards (8 = 2 + 3 + 3)
    for r in [2usize, 3, 4] {
        let rt = Runtime::sharded(r);
        let (got_pre, got_step) = run(&rt);
        assert_eq!(
            bits(&got_pre),
            bits(&want_pre),
            "sharded prefill (R={r}) diverged from serial decode"
        );
        assert_eq!(
            bits(&got_step),
            bits(&want_step),
            "sharded decode_step (R={r}) diverged from serial decode"
        );
    }
}

#[test]
fn sharded_ragged_decode_is_bit_identical_to_serial() {
    // mixed per-request depths shard with their requests: each replica
    // sees its own slice of `lens`, and the concatenated records must
    // equal the serial ragged run bit for bit
    let _g = lock();
    let (cfg, theta, toks) = setup("gpt_base_sim");
    let (b, s) = (cfg.batch, cfg.seq_len);
    let lens = ragged(&cfg);
    let next: Vec<i32> = (0..b).map(|bi| toks[bi * s + lens[bi] as usize]).collect();

    let run = |rt: &Runtime| -> (Vec<f32>, Vec<f32>) {
        let pf = rt.exe("prefill__gpt_base_sim").unwrap();
        let dc = rt.exe("decode_step__gpt_base_sim").unwrap();
        let recs = rt
            .call(
                &pf,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::I32(&toks, vec![b, s]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        let stepped = rt
            .call(
                &dc,
                &[
                    Arg::F32(&theta, vec![theta.len()]),
                    Arg::Buf(&recs),
                    Arg::I32(&next, vec![b]),
                    Arg::I32(&lens, vec![b]),
                ],
            )
            .unwrap();
        (rt.read_f32(&recs).unwrap(), rt.read_f32(&stepped).unwrap())
    };

    let serial = Runtime::reference();
    let (want_pre, want_step) = run(&serial);
    for r in [2usize, 3, 4] {
        let rt = Runtime::sharded(r);
        let (got_pre, got_step) = run(&rt);
        assert_eq!(
            bits(&got_pre),
            bits(&want_pre),
            "sharded ragged prefill (R={r}) diverged from serial"
        );
        assert_eq!(
            bits(&got_step),
            bits(&want_step),
            "sharded ragged decode_step (R={r}) diverged from serial"
        );
    }
}

#[test]
fn generation_is_identical_across_replica_counts() {
    let _g = lock();
    use multilevel::coordinator::{GenerateRequest, Generator, Sampler};
    let (cfg, theta, toks) = setup("gpt_nano");
    let plen = 4usize;
    let prompts: Vec<i32> = (0..cfg.batch)
        .flat_map(|bi| toks[bi * cfg.seq_len..bi * cfg.seq_len + plen].to_vec())
        .collect();
    let gen = cfg.seq_len - plen;
    let mut outs = Vec::new();
    for r in [1usize, 2, 4] {
        let rt = Runtime::sharded(r);
        let g = Generator::new(&rt, "gpt_nano").unwrap();
        let req = GenerateRequest::new(&prompts, plen)
            .max_new_tokens(gen)
            .sampler(Sampler::temperature(0.7, 99).unwrap());
        let out = g.generate(&rt, &theta, req).unwrap();
        assert_eq!(out.batch, cfg.batch);
        outs.push(out.tokens);
    }
    assert_eq!(outs[0], outs[1], "generation differs between R=1 and R=2");
    assert_eq!(outs[0], outs[2], "generation differs between R=1 and R=4");
    assert!(outs[0].iter().all(|t| t.len() == gen));
}

#[test]
fn backend_rejects_decode_artifacts_for_bidirectional_configs() {
    let _g = lock();
    let m = Manifest::builtin();
    let be = ReferenceBackend::new(&m);
    // graft the causal artifact onto a BERT config (an on-disk manifest
    // could claim this; the backend must refuse rather than mis-mask)
    let mut bad = m.artifact("decode_step__gpt_nano").unwrap().clone();
    bad.name = "decode_step__bert_nano".into();
    bad.config = "bert_nano".into();
    let err = be.prepare(&bad).unwrap_err().to_string();
    assert!(err.contains("causal"), "unexpected prepare error: {err}");
    assert!(err.contains("bert_nano"), "unexpected prepare error: {err}");
}
