//! The observability contract (`obs/`): tracing and metrics are
//! **observe-only**.
//!
//! Pinned here:
//! * **Bitwise parity** — a traced+journaled run is bit-identical to an
//!   untraced one for plain training, a 2-level `bert_nano` V-cycle and a
//!   serve trace replay, across `PALLAS_REF_THREADS` ∈ {1, 2, 4} and
//!   `PALLAS_REPLICAS` ∈ {1, 2}. Spans and journal rows never feed back
//!   into scheduling or numerics.
//! * **Ring buffers** — wraparound keeps the newest `RING_CAP` spans and
//!   reports exactly how many older spans were overwritten.
//! * **Chrome export** — the trace file is valid JSON and every track's
//!   timestamps are non-decreasing, so Perfetto renders it directly.
//! * **Journals** — metrics JSONL rows round-trip through `util/json.rs`
//!   and feed `multilevel report`.
//! * **Flags and guards** — `active()` composes the two flags; disabled
//!   guards record nothing; nesting subtracts child time from self time;
//!   pool kernel context restores on drop.
//!
//! The obs flags and span rings are process-global, so every test
//! serializes on a local mutex and restores a clean (disabled, drained)
//! state on both entry and exit.

use std::sync::{Mutex, MutexGuard};

use multilevel::coordinator::{run_vcycle_resumable, synthetic_trace, train_resumable,
                              RunOpts, ServeEngine, ServeOpts, TrafficSpec};
use multilevel::obs;
use multilevel::obs::tracer::{self, SpanKind, NO_TRACK, RING_CAP};
use multilevel::runtime::{init_theta, Runtime, State};
use multilevel::util::json::Json;
use multilevel::util::threadpool;
use multilevel::util::tmp::TempDir;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disable both flags and drop all recorded state — called on entry *and*
/// exit of every test so a panicking test cannot poison the next one.
fn clean() {
    obs::set_tracing(false);
    obs::metrics::close_global_journal();
    obs::set_metrics(false); // closing the journal does not clear the flag
    tracer::reset_spans();
    obs::metrics::reset_metrics();
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn state_bits(rt: &Runtime, st: &State) -> Vec<u32> {
    bits(&st.to_host(rt).unwrap())
}

fn runtime_for(replicas: usize) -> Runtime {
    if replicas == 1 {
        Runtime::reference()
    } else {
        Runtime::sharded(replicas)
    }
}

/// Run `f` twice per (threads, replicas) combination — once untraced,
/// once with tracing + a metrics journal — and assert the projections are
/// identical. The traced run also exercises the Chrome export.
fn assert_parity<T, F>(tag: &str, dir: &TempDir, mut f: F)
where
    T: PartialEq + std::fmt::Debug,
    F: FnMut(&Runtime) -> T,
{
    let before = threadpool::threads();
    for threads in [1usize, 2, 4] {
        threadpool::set_threads(threads);
        for replicas in [1usize, 2] {
            let rt = runtime_for(replicas);
            clean();
            let plain = f(&rt);

            let journal = dir.file(&format!("{tag}_{threads}x{replicas}.jsonl"));
            obs::set_tracing(true);
            obs::metrics::open_global_journal(&journal).unwrap();
            let traced = f(&rt);
            let trace = dir.file(&format!("{tag}_{threads}x{replicas}.trace.json"));
            obs::chrome::write_chrome_trace(&trace).unwrap();
            obs::metrics::close_global_journal();
            clean();

            assert_eq!(
                traced, plain,
                "{tag}: traced run diverged at {threads} threads, {replicas} replicas"
            );
            assert!(journal.exists() && trace.exists());
        }
    }
    threadpool::set_threads(before);
}

// ---------------------------------------------------------------------------
// Bitwise parity: train, V-cycle, serve
// ---------------------------------------------------------------------------

#[test]
fn traced_train_step_is_bit_identical_to_untraced() {
    let _g = lock();
    let dir = TempDir::new("obs_train");
    assert_parity("train", &dir, |rt| {
        let (st, loss) =
            train_resumable(rt, "gpt_nano", 6, 1e-3, 42, 0, 2, None, None).unwrap();
        (state_bits(rt, &st), loss.to_bits())
    });
    clean();
}

#[test]
fn traced_vcycle_is_bit_identical_to_untraced() {
    let _g = lock();
    let dir = TempDir::new("obs_vcycle");
    let mut opts = RunOpts::quick("bert_nano", 16);
    opts.alpha = 0.5;
    opts.eval_every = 8;
    opts.val_batches = 1;
    opts.budget_mult = 1.0;
    assert_parity("vcycle", &dir, |rt| {
        let st = run_vcycle_resumable(rt, &opts, 2, None, None).unwrap();
        state_bits(rt, &st)
    });
    clean();
}

#[test]
fn traced_serve_replay_is_bit_identical_to_untraced() {
    let _g = lock();
    let dir = TempDir::new("obs_serve");
    let rt0 = Runtime::reference();
    let cfg = rt0.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let trace = synthetic_trace(&cfg, &TrafficSpec::quick(21, 10)).unwrap();
    let opts = ServeOpts {
        max_batch: 2,
        max_queue: 10,
        temperature: 0.7,
        seed: 9,
        ..ServeOpts::default()
    };
    assert_parity("serve", &dir, |rt| {
        let eng = ServeEngine::new(rt, "gpt_nano", opts.clone()).unwrap();
        let rep = eng.run(rt, &theta, &trace).unwrap();
        // the replay-relevant outcome: everything except wall-clock
        let mut v: Vec<(usize, usize, Vec<i32>)> =
            rep.served.iter().map(|r| (r.id, r.finish_step, r.tokens.clone())).collect();
        v.push((usize::MAX, rep.steps, rep.rejected.iter().map(|&i| i as i32).collect()));
        v
    });
    clean();
}

// ---------------------------------------------------------------------------
// Ring buffers
// ---------------------------------------------------------------------------

#[test]
fn ring_wraparound_keeps_newest_spans_and_reports_drop_count() {
    let _g = lock();
    clean();
    obs::set_tracing(true);
    let extra = 100u64;
    let total = RING_CAP as u64 + extra;
    for i in 0..total {
        // synthesized spans land in this thread's ring in push order
        tracer::record_span(SpanKind::Gemm, NO_TRACK, i, 1);
    }
    obs::set_tracing(false);
    // `clean()` drained every ring, so the only non-empty one is ours
    let rings = tracer::drain_rings();
    assert_eq!(rings.len(), 1, "exactly one thread recorded spans");
    let ring = &rings[0];
    assert_eq!(ring.dropped, extra, "drop count must equal the overwritten spans");
    assert_eq!(ring.spans.len(), RING_CAP);
    // oldest-first drain of exactly the newest RING_CAP spans
    for (j, rec) in ring.spans.iter().enumerate() {
        assert_eq!(rec.start_ns, extra + j as u64);
    }
    clean();
}

// ---------------------------------------------------------------------------
// Chrome export
// ---------------------------------------------------------------------------

#[test]
fn chrome_trace_is_valid_json_with_non_decreasing_track_timestamps() {
    let _g = lock();
    clean();
    let before = threadpool::threads();
    threadpool::set_threads(2);
    obs::set_tracing(true);
    // a real sharded run: artifact spans on the drivers, kernel spans on
    // the pool workers, produce/merge/wait spans on the replica tracks
    let rt = Runtime::sharded(2);
    train_resumable(&rt, "gpt_nano", 3, 1e-3, 42, 0, 2, None, None).unwrap();
    obs::set_tracing(false);
    threadpool::set_threads(before);

    let dir = TempDir::new("obs_chrome");
    let path = dir.file("t.trace.json");
    let sum = obs::chrome::write_chrome_trace(&path).unwrap();
    assert!(sum.events > 0 && sum.tracks > 0, "empty trace from a traced run");

    let text = std::fs::read_to_string(&path).unwrap();
    let v = Json::parse(&text).expect("trace must be valid JSON");
    assert_eq!(v.get("displayTimeUnit").as_str(), Some("ms"));
    let events = obs::chrome::parse_trace_events(&text).unwrap();
    assert_eq!(events.len(), sum.events, "summary event count must match the file");

    // per-track timestamps are non-decreasing (Perfetto renders directly)
    let mut last: std::collections::BTreeMap<&str, f64> = Default::default();
    for (track, ts, dur, _name, _cat) in &events {
        assert!(*ts >= 0.0 && *dur >= 0.0);
        let prev = last.entry(track.as_str()).or_insert(0.0);
        assert!(*ts >= *prev, "track '{track}' went backwards: {ts} < {prev}");
        *prev = *ts;
    }

    let cats: std::collections::BTreeSet<&str> =
        events.iter().map(|(_, _, _, _, c)| c.as_str()).collect();
    assert!(cats.contains("artifact"), "no artifact spans in {cats:?}");
    assert!(cats.contains("allreduce_produce"), "no replica spans in {cats:?}");
    let tracks: std::collections::BTreeSet<&str> =
        events.iter().map(|(t, _, _, _, _)| t.as_str()).collect();
    assert!(tracks.iter().any(|t| t.starts_with("replica-")),
            "no replica track in {tracks:?}");
    clean();
}

// ---------------------------------------------------------------------------
// Metrics journals
// ---------------------------------------------------------------------------

#[test]
fn metrics_journal_rows_round_trip_through_json() {
    let _g = lock();
    clean();
    let dir = TempDir::new("obs_journal");
    let path = dir.file("m.jsonl");
    obs::metrics::open_global_journal(&path).unwrap();
    assert!(obs::metrics_enabled(), "opening the journal must enable metrics");

    let rt = Runtime::reference();
    train_resumable(&rt, "gpt_nano", 3, 1e-3, 42, 0, 2, None, None).unwrap();
    let cfg = rt.cfg("gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 5);
    let trace = synthetic_trace(&cfg, &TrafficSpec::quick(7, 6)).unwrap();
    let eng = ServeEngine::new(&rt, "gpt_nano",
                               ServeOpts { max_queue: 6, ..ServeOpts::default() })
        .unwrap();
    eng.run(&rt, &theta, &trace).unwrap();
    obs::metrics::close_global_journal();
    clean();

    let text = std::fs::read_to_string(&path).unwrap();
    let mut steps = 0usize;
    let mut serves = 0usize;
    for line in text.lines() {
        let row = Json::parse(line).expect("every journal line must be one JSON object");
        // round-trip: render and re-parse to the identical value
        assert_eq!(Json::parse(&row.to_string()).unwrap(), row);
        match row.get("row").as_str() {
            Some("step") => {
                steps += 1;
                assert_eq!(row.get("config").as_str(), Some("gpt_nano"));
                assert!(row.get("mfu").as_f64().unwrap() >= 0.0);
                assert!(row.get("flops_cum").as_f64().unwrap()
                            >= row.get("flops_step").as_f64().unwrap());
                assert!(row.get("roofline_gflops").as_f64().unwrap() > 0.0);
                assert!(row.get("ar_wait_ms").as_f64().is_some());
            }
            Some("serve") => {
                serves += 1;
                assert!(row.get("queue_depth").as_usize().is_some());
                let hist = row.get("lat_hist_log2ms").as_arr().unwrap();
                assert_eq!(hist.len(), obs::metrics::LAT_BUCKETS);
            }
            other => panic!("unknown row type {other:?} in {line}"),
        }
    }
    assert_eq!(steps, 3, "one step row per training step");
    assert!(serves >= 1, "the serve run must emit at least its final tick");

    // the same journal drives `multilevel report`
    let tables = obs::report::summarize(&path).unwrap();
    let rendered: String = tables.iter().map(|t| t.render()).collect();
    assert!(rendered.contains("MFU per phase"), "no MFU table in:\n{rendered}");
    assert!(rendered.contains("gpt_nano"));
}

// ---------------------------------------------------------------------------
// Flags, guards, nesting, pool context
// ---------------------------------------------------------------------------

#[test]
fn flags_compose_and_disabled_guards_record_nothing() {
    let _g = lock();
    clean();
    assert!(!obs::active());
    obs::set_metrics(true);
    assert!(obs::active() && obs::metrics_enabled() && !obs::tracing_enabled());
    obs::set_metrics(false);
    obs::set_tracing(true);
    assert!(obs::active() && obs::tracing_enabled() && !obs::metrics_enabled());
    obs::set_tracing(false);
    assert!(!obs::active());

    // disabled guards are inert: no aggregates, no ring contents
    {
        let _a = obs::span(SpanKind::CkptSave);
        let _b = obs::span_named(SpanKind::Gemm, "gemm_64");
        let _c = obs::artifact_span("train_step__gpt_nano");
        obs::record_since(SpanKind::ServeQueueWait, std::time::Instant::now());
        tracer::record_span(SpanKind::AllreduceWait, 1, 0, 10);
    }
    assert!(tracer::kind_stats().is_empty(), "disabled spans must not aggregate");
    assert!(tracer::drain_rings().is_empty(), "disabled spans must not hit the rings");
    clean();
}

#[test]
fn nested_spans_subtract_child_time_from_self_time() {
    let _g = lock();
    clean();
    obs::set_tracing(true);
    {
        let _outer = obs::span(SpanKind::Artifact);
        {
            let _inner = obs::span(SpanKind::Gemm);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    obs::set_tracing(false);
    let stats = tracer::kind_stats();
    let get = |k: SpanKind| stats.iter().find(|s| s.kind == k).copied().unwrap();
    let outer = get(SpanKind::Artifact);
    let inner = get(SpanKind::Gemm);
    assert_eq!((outer.count, inner.count), (1, 1));
    assert!(inner.total_ns >= 2_000_000, "inner span must cover the sleep");
    assert!(outer.total_ns >= inner.total_ns, "outer encloses inner");
    assert!(
        outer.self_ns <= outer.total_ns - inner.total_ns,
        "outer self time ({}) must exclude the nested child ({} of {})",
        outer.self_ns, inner.total_ns, outer.total_ns
    );
    assert_eq!(inner.self_ns, inner.total_ns, "leaf self time equals total");
    clean();
}

#[test]
fn pool_kernel_context_restores_on_drop() {
    let _g = lock();
    clean();
    assert_eq!(obs::tracer::current_pool_ctx(), obs::CTX_NONE);
    {
        let _g1 = obs::set_pool_ctx(SpanKind::Gemm);
        assert_eq!(obs::tracer::current_pool_ctx(), SpanKind::Gemm as u8);
        {
            let _g2 = obs::set_pool_ctx(SpanKind::Attention);
            assert_eq!(obs::tracer::current_pool_ctx(), SpanKind::Attention as u8);
        }
        assert_eq!(obs::tracer::current_pool_ctx(), SpanKind::Gemm as u8);
    }
    assert_eq!(obs::tracer::current_pool_ctx(), obs::CTX_NONE);
    clean();
}
