//! Workspace-arena allocation probe: a counting global allocator proves
//! that steady-state `train_step_into` / `train_grad_into` perform **zero**
//! heap allocations after warm-up.
//!
//! The probe pins the kernel pool to one thread: with a single thread every
//! `parallel_for` runs inline, so the measurement sees exactly the compute
//! path's allocations (with more threads the *scheduler* allocates dispatch
//! bookkeeping — an `Arc` batch and channel nodes per fan-out — which is
//! orthogonal to the tensor-allocation contract the arena guarantees;
//! kernel results are bit-identical either way, see `test_threads.rs`).
//!
//! Tests in this file share one global counter, so they serialize on a
//! local mutex.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use multilevel::runtime::reference::exec::{
    self, train_grad_into, train_step_into, BatchRef, Workspace,
};
use multilevel::runtime::{init_theta, Manifest, ModelCfg};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

/// Counts every allocation (alloc, alloc_zeroed, realloc) in the process.
struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn gpt_setup(name: &str) -> (ModelCfg, Vec<f32>, Vec<i32>) {
    let m = Manifest::builtin();
    let cfg = m.cfg(name).unwrap().clone();
    let theta = init_theta(&cfg, 3);
    let mut state = vec![0.0f32; cfg.state_len()];
    state[1..1 + cfg.n_params].copy_from_slice(&theta);
    let c = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(9);
    let mut toks = Vec::new();
    for _ in 0..cfg.batch {
        toks.extend(c.sequence(cfg.seq_len, &mut rng));
    }
    (cfg, state, toks)
}

#[test]
fn steady_state_train_step_performs_zero_heap_allocations() {
    let _g = lock();
    let before_threads = threadpool::threads();
    threadpool::set_threads(1);

    let (cfg, state, toks) = gpt_setup("gpt_nano");
    let batch = BatchRef::Gpt { tokens: &toks };
    let mut ws = Workspace::new();
    let mut cur = state;
    let mut next = Vec::new();

    // warm-up: first step allocates the arena, the next two settle the
    // ping-pong output buffers and any second-order pool pairings
    for step in 1..=3 {
        train_step_into(&cfg, &cur, &batch, 1e-3, step as f32, &mut ws, &mut next).unwrap();
        std::mem::swap(&mut cur, &mut next);
    }

    let warm_misses = ws.alloc_misses();
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for step in 4..=8 {
        train_step_into(&cfg, &cur, &batch, 1e-3, step as f32, &mut ws, &mut next).unwrap();
        std::mem::swap(&mut cur, &mut next);
    }
    let delta = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state train_step allocated {delta} times over 5 steps"
    );
    assert_eq!(ws.alloc_misses(), warm_misses, "arena kept missing after warm-up");
    assert!(cur[0].is_finite());

    threadpool::set_threads(before_threads);
}

#[test]
fn steady_state_train_grad_performs_zero_heap_allocations() {
    let _g = lock();
    let before_threads = threadpool::threads();
    threadpool::set_threads(1);

    let (cfg, state, toks) = gpt_setup("gpt_nano");
    let theta = state[1..1 + cfg.n_params].to_vec();
    // shard-sized batch: the sharded backend's per-replica call shape
    let shard = &toks[..2 * cfg.seq_len];
    let batch = BatchRef::Gpt { tokens: shard };
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    for _ in 0..3 {
        train_grad_into(&cfg, &theta, &batch, &mut ws, &mut out).unwrap();
    }
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..5 {
        train_grad_into(&cfg, &theta, &batch, &mut ws, &mut out).unwrap();
    }
    let delta = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "steady-state train_grad allocated {delta} times over 5 calls"
    );

    threadpool::set_threads(before_threads);
}

#[test]
fn eval_loss_arena_misses_stabilize() {
    let _g = lock();
    let before_threads = threadpool::threads();
    threadpool::set_threads(1);

    let (cfg, state, toks) = gpt_setup("gpt_nano");
    let theta = &state[1..1 + cfg.n_params];
    let batch = BatchRef::Gpt { tokens: &toks };
    let mut ws = Workspace::new();
    let mut first = f32::NAN;
    for _ in 0..2 {
        first = exec::eval_loss_ws(&cfg, theta, &batch, &mut ws).unwrap();
    }
    let warm = ws.alloc_misses();
    for _ in 0..4 {
        let l = exec::eval_loss_ws(&cfg, theta, &batch, &mut ws).unwrap();
        assert_eq!(l.to_bits(), first.to_bits(), "eval not deterministic");
    }
    assert_eq!(ws.alloc_misses(), warm, "eval_loss kept allocating after warm-up");

    threadpool::set_threads(before_threads);
}
