//! The checkpoint/resume determinism contract and fault-injection suite.
//!
//! Headline guarantee: running 2N steps equals running N steps,
//! checkpointing, reloading and running N more — **bit-identical** — for
//! plain training, mid-V-cycle (including across coalesce/refine
//! boundaries) and sharded runs. Every fault-injection case (truncation,
//! bit flip, wrong version, mismatched config, mismatched topology) must
//! fail closed with a descriptive error.
//!
//! The V-cycle tests run on [`Runtime::load_default`], so the `rust-sharded`
//! CI cell (`PALLAS_REPLICAS=2`) exercises mid-V-cycle resume under R=2.

use multilevel::coordinator::{run_vcycle_resumable, train_resumable, CheckpointManager,
                              Harness, Method, RunOpts};
use multilevel::runtime::checkpoint::tmp_path;
use multilevel::runtime::{Checkpoint, Manifest, Runtime, State};
use multilevel::util::json::Json;
use multilevel::util::tmp::TempDir;
use multilevel::util::{prop, rng::Rng};

const LR: f32 = 1e-3;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn state_bits(rt: &Runtime, st: &State) -> Vec<u32> {
    bits(&st.to_host(rt).unwrap())
}

fn train_gpt_nano(
    rt: &Runtime,
    steps: usize,
    mgr: Option<&CheckpointManager>,
    resume: Option<Checkpoint>,
) -> (Vec<u32>, f32) {
    let (st, loss) = train_resumable(rt, "gpt_nano", steps, LR, 42, 0, 2, mgr, resume).unwrap();
    (state_bits(rt, &st), loss)
}

// ---------------------------------------------------------------------------
// Bit-identical resume: plain training
// ---------------------------------------------------------------------------

#[test]
fn plain_train_resume_bit_identical() {
    let rt = Runtime::reference();
    let (full, full_loss) = train_gpt_nano(&rt, 12, None, None);

    let dir = TempDir::new("ckpt_plain");
    let mgr = CheckpointManager::new(dir.file("ck"), 6).unwrap().with_history(true);
    train_gpt_nano(&rt, 12, Some(&mgr), None);

    // "kill at N": resume the 2N-step run from its mid-run snapshot
    let snap = Checkpoint::load(&mgr.dir().join("ckpt_p01_s00006.ckpt")).unwrap();
    assert_eq!((snap.kind.as_str(), snap.step), ("train", 6));
    assert_ne!(snap.stream_cursor, [0; 4], "mid-run snapshot must carry the stream cursor");
    let (resumed, resumed_loss) = train_gpt_nano(&rt, 12, None, Some(snap));
    assert_eq!(full, resumed, "resumed run diverged from the uninterrupted one");
    assert_eq!(full_loss.to_bits(), resumed_loss.to_bits());
}

#[test]
fn resume_at_completion_is_a_noop() {
    let rt = Runtime::reference();
    let dir = TempDir::new("ckpt_done");
    let mgr = CheckpointManager::new(dir.file("ck"), 0).unwrap();
    let (full, _) = train_gpt_nano(&rt, 5, Some(&mgr), None);
    let done = mgr.load_latest().unwrap().unwrap();
    assert_eq!(done.step, 5);
    let (again, _) = train_gpt_nano(&rt, 5, None, Some(done));
    assert_eq!(full, again);
}

// ---------------------------------------------------------------------------
// Bit-identical resume: mid-V-cycle and at level boundaries
// ---------------------------------------------------------------------------

fn vopts() -> RunOpts {
    let mut o = RunOpts::quick("bert_nano", 40);
    o.alpha = 0.5; // paper: α = 0.5 for BERT
    o.eval_every = 10;
    o.val_batches = 2;
    o.budget_mult = 1.0;
    o
}

#[test]
fn vcycle_resumable_matches_harness_bitwise() {
    // the resumable driver must mirror Harness::run_vcycle seed-for-seed —
    // otherwise "resume reproduces the run" guards the wrong program
    let rt = Runtime::load_default().unwrap();
    let ours = run_vcycle_resumable(&rt, &vopts(), 2, None, None).unwrap();
    let h = Harness::new(&rt, vopts());
    let harness = h.run_method_state(&Method::VCycle { levels: 2, fit: false }).unwrap();
    assert_eq!(
        state_bits(&rt, &ours),
        state_bits(&rt, &harness),
        "resumable V-cycle diverged from the harness program"
    );
    assert_eq!(ours.flops.to_bits(), harness.flops.to_bits());
}

#[test]
fn vcycle_resume_mid_level_and_at_boundaries_bit_identical() {
    let rt = Runtime::load_default().unwrap();
    let opts = vopts();
    let full = run_vcycle_resumable(&rt, &opts, 2, None, None).unwrap();
    let full_bits = state_bits(&rt, &full);

    let dir = TempDir::new("ckpt_vcycle");
    let mgr = CheckpointManager::new(dir.file("ck"), 7).unwrap().with_history(true);
    run_vcycle_resumable(&rt, &opts, 2, Some(&mgr), None).unwrap();

    // mid-level: inside the coarse (bert_nano_lv2) phase
    let mid = Checkpoint::load(&mgr.dir().join("ckpt_p02_s00007.ckpt")).unwrap();
    assert_eq!(mid.config, "bert_nano_lv2");
    assert!(mid.step > 0 && mid.step < opts.e_small());
    let resumed = run_vcycle_resumable(&rt, &opts, 2, None, Some(mid)).unwrap();
    assert_eq!(state_bits(&rt, &resumed), full_bits, "mid-level resume diverged");

    // boundaries: right after coalesce (p2 s0) and right after refine (p3 s0)
    for name in ["ckpt_p02_s00000.ckpt", "ckpt_p03_s00000.ckpt"] {
        let snap = Checkpoint::load(&mgr.dir().join(name)).unwrap();
        assert_eq!(snap.step, 0);
        let resumed = run_vcycle_resumable(&rt, &opts, 2, None, Some(snap)).unwrap();
        assert_eq!(state_bits(&rt, &resumed), full_bits, "boundary resume diverged ({name})");
    }
}

// ---------------------------------------------------------------------------
// Bit-identical resume: sharded R ∈ {2, 3}
// ---------------------------------------------------------------------------

#[test]
fn sharded_resume_parity_r2_r3() {
    for r in [2usize, 3] {
        let rt = Runtime::sharded(r);
        assert_eq!(rt.shard_topology().0, r);
        let (full, _) = train_gpt_nano(&rt, 10, None, None);

        let dir = TempDir::new("ckpt_sharded");
        let mgr = CheckpointManager::new(dir.file("ck"), 5).unwrap().with_history(true);
        train_gpt_nano(&rt, 10, Some(&mgr), None);
        let snap = Checkpoint::load(&mgr.dir().join("ckpt_p01_s00005.ckpt")).unwrap();
        assert_eq!(snap.replicas, r);
        let (resumed, _) = train_gpt_nano(&rt, 10, None, Some(snap));
        assert_eq!(full, resumed, "R={r}: sharded resume diverged");
    }
}

#[test]
fn replica_topology_mismatch_fails_closed() {
    let rt2 = Runtime::sharded(2);
    let dir = TempDir::new("ckpt_topo");
    let mgr = CheckpointManager::new(dir.file("ck"), 0).unwrap();
    train_gpt_nano(&rt2, 4, Some(&mgr), None);
    let snap = mgr.load_latest().unwrap().unwrap();
    let rt3 = Runtime::sharded(3);
    let err = train_resumable(&rt3, "gpt_nano", 4, LR, 42, 0, 2, None, Some(snap))
        .unwrap_err()
        .to_string();
    assert!(err.contains("--replicas 2"), "no topology guidance in: {err}");
}

// ---------------------------------------------------------------------------
// Fault injection: every corruption fails closed, descriptively
// ---------------------------------------------------------------------------

#[test]
fn fault_injection_fails_closed() {
    let rt = Runtime::reference();
    let dir = TempDir::new("ckpt_fault");
    let mgr = CheckpointManager::new(dir.file("ck"), 0).unwrap();
    train_gpt_nano(&rt, 3, Some(&mgr), None);
    let good = std::fs::read(mgr.latest_path()).unwrap();

    // truncated file
    let p = dir.file("trunc.ckpt");
    std::fs::write(&p, &good[..good.len() / 2]).unwrap();
    let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
    assert!(err.contains("truncated"), "{err}");

    // flipped payload byte -> CRC mismatch
    let p = dir.file("flip.ckpt");
    let mut bad = good.clone();
    let mid = bad.len() - 10;
    bad[mid] ^= 0x01;
    std::fs::write(&p, bad).unwrap();
    let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
    assert!(err.contains("crc"), "{err}");

    // wrong version, named in the error
    let p = dir.file("ver.ckpt");
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&9u32.to_le_bytes());
    std::fs::write(&p, bad).unwrap();
    let err = format!("{:#}", Checkpoint::load(&p).unwrap_err());
    assert!(err.contains("version 9"), "{err}");

    // mismatched config: both names in the error, trainer never built
    let snap = mgr.load_latest().unwrap().unwrap();
    let err = format!(
        "{:#}",
        train_resumable(&rt, "bert_nano", 3, LR, 42, 0, 2, None, Some(snap.clone()))
            .unwrap_err()
    );
    assert!(err.contains("gpt_nano") && err.contains("bert_nano"), "{err}");

    // mismatched run parameters fail closed too
    let err = format!(
        "{:#}",
        train_resumable(&rt, "gpt_nano", 99, LR, 42, 0, 2, None, Some(snap.clone()))
            .unwrap_err()
    );
    assert!(err.contains("steps"), "{err}");
    let err = format!(
        "{:#}",
        train_resumable(&rt, "gpt_nano", 3, LR, 7, 0, 2, None, Some(snap)).unwrap_err()
    );
    assert!(err.contains("seed"), "{err}");

    // after all those failures, a fresh run is still exactly reproducible —
    // failed loads leave no state behind
    let (a, _) = train_gpt_nano(&rt, 3, None, None);
    let (b, _) = train_gpt_nano(&rt, 3, None, None);
    assert_eq!(a, b);
}

#[test]
fn atomic_write_no_torn_checkpoint() {
    let rt = Runtime::reference();
    let dir = TempDir::new("ckpt_atomic");
    let mgr = CheckpointManager::new(dir.file("ck"), 0).unwrap();
    let tmp = tmp_path(&mgr.latest_path());

    // crash before the first rename: only a torn tmp exists, which the
    // loader never consults — the run simply starts fresh
    std::fs::write(&tmp, b"partial garbage from a dead process").unwrap();
    assert!(mgr.load_latest().unwrap().is_none());

    // a completed save lands atomically and clears the tmp
    train_gpt_nano(&rt, 2, Some(&mgr), None);
    assert!(!tmp.exists(), "save left its temp file behind");
    let ck = mgr.load_latest().unwrap().unwrap();
    assert_eq!(ck.step, 2);

    // crash of a *later* save between temp-write and rename: the stale tmp
    // must not shadow the last complete checkpoint
    std::fs::write(&tmp, b"crashed mid-write").unwrap();
    assert_eq!(mgr.load_latest().unwrap().unwrap(), ck);
}

// ---------------------------------------------------------------------------
// Property: round-trip across every registry config
// ---------------------------------------------------------------------------

#[test]
fn property_roundtrip_every_registry_config() {
    let manifest = Manifest::builtin();
    let dir = TempDir::new("ckpt_prop");
    assert!(!manifest.configs.is_empty());
    for (name, cfg) in &manifest.configs {
        // big configs round-trip a truncated state (the full-size path is
        // pinned separately below) — the header/cursor/payload machinery
        // under test is identical either way
        let state_len = cfg.state_len().min(4096);
        let path = dir.file(&format!("{name}.ckpt"));
        prop::check(
            &format!("ckpt-roundtrip-{name}"),
            0xC0FFEE,
            3,
            |r: &mut Rng| {
                (
                    r.next_u64(),
                    [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
                    r.below(10_000),
                    r.below(50),
                    r.f64() * 1e12,
                    r.next_u64() as u32,
                )
            },
            prop::no_shrink,
            |&(seed, cursor, step, phase, flops, pat)| {
                let state: Vec<f32> = (0..state_len)
                    .map(|i| f32::from_bits(((i as u32).wrapping_mul(2_654_435_761) ^ pat) >> 2))
                    .collect();
                let ck = Checkpoint {
                    kind: "vcycle".into(),
                    config: name.clone(),
                    n_params: cfg.n_params,
                    level: 1,
                    phase,
                    step,
                    flops,
                    replicas: 3,
                    seed,
                    stream_cursor: cursor,
                    extra: Json::Null,
                    vectors: vec![("state".into(), state.clone())],
                };
                ck.save(&path).map_err(|e| format!("{e:#}"))?;
                let back = Checkpoint::load(&path).map_err(|e| format!("{e:#}"))?;
                if bits(back.vector("state").unwrap()) != bits(&state) {
                    return Err(format!("{name}: state vector changed across save/load"));
                }
                if back != ck {
                    return Err(format!("{name}: header changed across save/load"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn full_state_roundtrip_gpt_base_sim_exact() {
    // the bench row's config, at full state size, bit-exact
    let manifest = Manifest::builtin();
    let cfg = manifest.cfg("gpt_base_sim").unwrap();
    let state: Vec<f32> = (0..cfg.state_len())
        .map(|i| f32::from_bits((i as u32).wrapping_mul(2_654_435_761) >> 2))
        .collect();
    let ck = Checkpoint {
        kind: "train".into(),
        config: cfg.name.clone(),
        n_params: cfg.n_params,
        level: 1,
        phase: 1,
        step: 123,
        flops: 4.5e9,
        replicas: 1,
        seed: u64::MAX,
        stream_cursor: [u64::MAX, 1, 2, 3],
        extra: Json::Null,
        vectors: vec![("state".into(), state.clone())],
    };
    let dir = TempDir::new("ckpt_full");
    let path = dir.file("full.ckpt");
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(bits(back.vector("state").unwrap()), bits(&state));
    assert_eq!(back.seed, u64::MAX);
    assert_eq!(back.stream_cursor[0], u64::MAX);
    assert_eq!(back, ck);
}
