//! Sharded-backend determinism and parity: for a fixed replica count `R`
//! every data-parallel path (train step, eval_loss, ft_step, distill_step,
//! attn_maps) must be bit-identical for every kernel thread count (the
//! all-reduce is a fixed tree over replicas with fixed-chunk reductions,
//! merged opportunistically but in a fixed pairing), and across replica
//! counts it must agree with the single-replica path to f32 tolerance —
//! including batch sizes that do not divide evenly by `R`, and a full
//! 2-level V-cycle. The overlapped all-reduce is additionally pinned
//! bit-for-bit against the post-barrier tree reduce it replaced.
//!
//! Tests serialize on a local mutex because the kernel pool is
//! process-global and the test harness runs tests concurrently.

use std::sync::{Mutex, MutexGuard};

use multilevel::coordinator::{Harness, Method, RunOpts, Trainer};
use multilevel::runtime::sharded::allreduce;
use multilevel::runtime::{
    init_state, init_theta, Arg, Backend, Manifest, ModelCfg, ReferenceBackend, Runtime,
    ShardedBackend,
};
use multilevel::util::rng::Rng;
use multilevel::util::threadpool;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Train `config` for `steps` on `rt`; returns (final host state, losses).
fn run_steps(rt: &Runtime, config: &str, steps: usize) -> (Vec<f32>, Vec<f32>) {
    let cfg = rt.cfg(config).unwrap().clone();
    let mut state = init_state(rt, &cfg, 11).unwrap();
    let mut tr = Trainer::new(rt, config, 0, 5, 1).unwrap();
    let mut losses = Vec::with_capacity(steps);
    for step in 1..=steps {
        let (s, loss) = tr.step(rt, &state, 1e-3, step).unwrap();
        assert!(loss.is_finite(), "{config} loss diverged at step {step}");
        state = s;
        losses.push(loss);
    }
    (state.to_host(rt).unwrap(), losses)
}

/// Robust state comparison: the losses must match tightly, and at most a
/// handful of parameters may deviate visibly (elements whose gradient is a
/// near-zero cancellation can flip sign under a different f32 summation
/// order, which AdamW's sign-like first step amplifies to ~lr — that is
/// expected float noise, not an error; a wrong shard weighting would move
/// *every* element).
fn assert_state_close(got: &[f32], want: &[f32], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: state length");
    assert!(
        (got[0] - want[0]).abs() < 1e-3,
        "{label}: loss {} vs {}",
        got[0],
        want[0]
    );
    let mut max = 0.0f32;
    let mut off = 0usize;
    for (g, w) in got[1..].iter().zip(&want[1..]) {
        let d = (g - w).abs();
        if d > 1e-4 {
            off += 1;
        }
        if d > max {
            max = d;
        }
    }
    let frac = off as f64 / (got.len() - 1) as f64;
    assert!(
        frac < 1e-3,
        "{label}: {off} elements ({frac:.2e}) deviate > 1e-4 (max {max})"
    );
    assert!(max < 5e-2, "{label}: max deviation {max}");
}

#[test]
fn sharded_steps_bit_identical_across_thread_counts() {
    let _g = lock();
    let before = threadpool::threads();
    for replicas in [1usize, 2, 4] {
        let rt = Runtime::sharded(replicas);
        let run = |threads: usize| {
            threadpool::set_threads(threads);
            run_steps(&rt, "gpt_base_sim", 2).0
        };
        let t1 = run(1);
        let t2 = run(2);
        let t8 = run(8);
        assert_eq!(bits(&t1), bits(&t2), "R={replicas}: 1 vs 2 threads diverged");
        assert_eq!(bits(&t1), bits(&t8), "R={replicas}: 1 vs 8 threads diverged");
    }
    threadpool::set_threads(before);
}

#[test]
fn sharded_matches_unsharded_within_tolerance() {
    let _g = lock();
    let reference = Runtime::reference();
    let (base_state, base_losses) = run_steps(&reference, "gpt_base_sim", 2);
    for replicas in [2usize, 4] {
        let rt = Runtime::sharded(replicas);
        let (state, losses) = run_steps(&rt, "gpt_base_sim", 2);
        for (l, b) in losses.iter().zip(&base_losses) {
            assert!((l - b).abs() < 1e-3, "R={replicas}: loss {l} vs {b}");
        }
        assert_state_close(&state, &base_state, &format!("R={replicas}"));
    }
}

#[test]
fn odd_batches_shard_without_remainder_loss() {
    let _g = lock();
    let before = threadpool::threads();
    // gpt_base_sim has batch 8: R=3 gives shards of 2/3/3 rows;
    // gpt_nano has batch 4: R=3 gives 1/1/2
    let reference = Runtime::reference();
    for config in ["gpt_nano", "gpt_base_sim"] {
        let (base_state, _) = run_steps(&reference, config, 2);
        let rt = Runtime::sharded(3);
        let run = |threads: usize| {
            threadpool::set_threads(threads);
            run_steps(&rt, config, 2).0
        };
        let t2 = run(2);
        let t8 = run(8);
        assert_eq!(bits(&t2), bits(&t8), "{config} R=3 diverged across threads");
        assert_state_close(&t2, &base_state, &format!("{config} R=3"));
    }
    threadpool::set_threads(before);
}

#[test]
fn replica_cap_of_one_is_bitwise_unsharded() {
    let _g = lock();
    let m = Manifest::builtin();
    let cfg = m.cfg("gpt_nano").unwrap().clone();
    let spec = m.artifact("train_step__gpt_nano").unwrap().clone();
    let theta = init_theta(&cfg, 11);
    let mut state = vec![0.0f32; cfg.state_len()];
    state[1..1 + cfg.n_params].copy_from_slice(&theta);
    let tokens: Vec<i32> =
        (0..cfg.batch * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
    let run = |be: &dyn Backend| {
        let out = be
            .execute(
                &spec,
                &[
                    Arg::F32(&state, vec![cfg.state_len()]),
                    Arg::I32(&tokens, vec![cfg.batch, cfg.seq_len]),
                    Arg::Scalar(1e-3),
                    Arg::Scalar(1.0),
                ],
            )
            .unwrap();
        be.read_f32(&out).unwrap()
    };

    let reference = ReferenceBackend::new(&m);
    let want = run(&reference);
    // capped to a single shard, the sharded backend must fall back to the
    // fused single-replica step — bit-for-bit
    let sharded = ShardedBackend::new(&m, 2);
    sharded.set_replica_cap(1);
    let got = run(&sharded);
    assert_eq!(bits(&got), bits(&want), "cap=1 is not the fused step");
    // uncapped, the sharded path runs and stays within tolerance
    sharded.set_replica_cap(usize::MAX);
    let sharded_out = run(&sharded);
    assert_state_close(&sharded_out, &want, "R=2 uncapped");
}

#[test]
fn vcycle_bert_nano_matches_single_replica() {
    let _g = lock();
    let before = threadpool::threads();
    let run = |rt: &Runtime| {
        let mut opts = RunOpts::quick("bert_nano", 12);
        opts.seed = 17;
        let h = Harness::new(rt, opts);
        let curve = h.run_method(&Method::VCycle { levels: 2, fit: false }, None).unwrap();
        let losses: Vec<f32> = curve.points.iter().map(|p| p.train_loss).collect();
        assert!(!losses.is_empty());
        losses
    };

    let single = run(&Runtime::reference());
    let rt4 = Runtime::sharded(4);
    threadpool::set_threads(2);
    let sharded_t2 = run(&rt4);
    threadpool::set_threads(8);
    let sharded_t8 = run(&rt4);
    threadpool::set_threads(before);

    // sharded V-cycle is bit-identical across thread counts...
    assert_eq!(bits(&sharded_t2), bits(&sharded_t8), "sharded V-cycle thread-dependent");
    // ...and tracks the single-replica run within f32 tolerance
    assert_eq!(single.len(), sharded_t2.len());
    for (i, (s, u)) in sharded_t2.iter().zip(&single).enumerate() {
        assert!(
            (s - u).abs() < 2e-2,
            "V-cycle loss diverged at point {i}: sharded {s} vs single {u}"
        );
    }
}

#[test]
fn topology_reports_through_runtime() {
    let _g = lock();
    let before = threadpool::threads();
    threadpool::set_threads(8);
    let rt = Runtime::sharded(4);
    let (r, t) = rt.shard_topology();
    assert_eq!(r, 4);
    assert_eq!(t, 2);
    let info = rt.device_info();
    assert!(info.contains("replicas=4"), "{info}");
    assert!(info.contains("threads-per-replica=2"), "{info}");
    assert!(rt.platform_name().contains("sharded"), "{}", rt.platform_name());
    // unsharded backends report a single replica owning the whole pool
    let single = Runtime::reference();
    assert_eq!(single.shard_topology(), (1, 8));
    threadpool::set_threads(before);
}

// ---------------------------------------------------------------------------
// Sharded eval / ft / distill / attn_maps (PR 4)
// ---------------------------------------------------------------------------

fn host_state(cfg: &ModelCfg, seed: u64) -> Vec<f32> {
    let theta = init_theta(cfg, seed);
    let mut state = vec![0.0f32; cfg.state_len()];
    state[1..1 + cfg.n_params].copy_from_slice(&theta);
    state
}

fn tokens_of(cfg: &ModelCfg, seed: u64) -> Vec<i32> {
    let c = multilevel::data::Corpus::new(cfg.vocab, 0);
    let mut rng = Rng::new(seed);
    let mut toks = Vec::new();
    for _ in 0..cfg.batch {
        toks.extend(c.sequence(cfg.seq_len, &mut rng));
    }
    toks
}

/// Masked-LM labels: every 7th position carries a target (shards get
/// uneven counts, exercising the count-weighted combine).
fn bert_labels(tokens: &[i32]) -> Vec<i32> {
    tokens
        .iter()
        .enumerate()
        .map(|(i, &t)| if i % 7 == 0 { t } else { -1 })
        .collect()
}

#[test]
fn sharded_eval_loss_bit_identical_across_thread_counts_and_close_to_unsharded() {
    let _g = lock();
    let before = threadpool::threads();
    let m = Manifest::builtin();
    for config in ["gpt_base_sim", "bert_nano"] {
        let cfg = m.cfg(config).unwrap().clone();
        let spec = m.artifact(&format!("eval_loss__{config}")).unwrap().clone();
        let state = host_state(&cfg, 23);
        let toks = tokens_of(&cfg, 31);
        let labels = bert_labels(&toks);
        let run = |be: &dyn Backend| {
            let mut args = vec![
                Arg::F32(&state, vec![cfg.state_len()]),
                Arg::I32(&toks, vec![cfg.batch, cfg.seq_len]),
            ];
            if config.starts_with("bert") {
                args.push(Arg::I32(&labels, vec![cfg.batch, cfg.seq_len]));
            }
            let out = be.execute(&spec, &args).unwrap();
            be.read_scalar(&out).unwrap()
        };
        let reference = ReferenceBackend::new(&m);
        let want = run(&reference);
        for replicas in [1usize, 2, 3, 4] {
            let be = ShardedBackend::new(&m, replicas);
            threadpool::set_threads(1);
            let t1 = run(&be);
            threadpool::set_threads(2);
            let t2 = run(&be);
            threadpool::set_threads(8);
            let t8 = run(&be);
            assert_eq!(
                t1.to_bits(),
                t2.to_bits(),
                "{config} R={replicas}: eval 1 vs 2 threads diverged"
            );
            assert_eq!(
                t1.to_bits(),
                t8.to_bits(),
                "{config} R={replicas}: eval 1 vs 8 threads diverged"
            );
            assert!(
                (t1 - want).abs() < 5e-4,
                "{config} R={replicas}: sharded eval {t1} vs unsharded {want}"
            );
            if replicas == 1 {
                assert_eq!(t1.to_bits(), want.to_bits(), "R=1 eval is not the fused path");
            }
        }
    }
    threadpool::set_threads(before);
}

#[test]
fn sharded_ft_step_matches_unsharded_and_is_thread_stable() {
    let _g = lock();
    let before = threadpool::threads();
    let m = Manifest::builtin();
    let cfg = m.cfg("bert_nano").unwrap().clone();
    let spec = m.artifact("ft_step__bert_nano").unwrap().clone();
    let n_ft = spec.meta.get("n_ft").as_usize().unwrap();
    // grafted state: theta ‖ small random head, zero moments
    let theta = init_theta(&cfg, 5);
    let mut state = vec![0.0f32; 3 * n_ft + 1];
    state[1..1 + cfg.n_params].copy_from_slice(&theta);
    let mut rng = Rng::new(77);
    for v in state[1 + cfg.n_params..1 + n_ft].iter_mut() {
        *v = (rng.f32() - 0.5) * 0.1;
    }
    let toks = tokens_of(&cfg, 41);
    let labels: Vec<i32> = (0..cfg.batch).map(|i| (i % 4) as i32).collect();
    let run = |be: &dyn Backend| {
        let out = be
            .execute(
                &spec,
                &[
                    Arg::F32(&state, vec![3 * n_ft + 1]),
                    Arg::I32(&toks, vec![cfg.batch, cfg.seq_len]),
                    Arg::I32(&labels, vec![cfg.batch]),
                    Arg::Scalar(1e-3),
                    Arg::Scalar(1.0),
                ],
            )
            .unwrap();
        be.read_f32(&out).unwrap()
    };
    let want = run(&ReferenceBackend::new(&m));
    for replicas in [2usize, 3] {
        let be = ShardedBackend::new(&m, replicas);
        threadpool::set_threads(2);
        let t2 = run(&be);
        threadpool::set_threads(8);
        let t8 = run(&be);
        assert_eq!(bits(&t2), bits(&t8), "ft R={replicas} thread-dependent");
        assert_state_close(&t2, &want, &format!("ft R={replicas}"));
    }
    threadpool::set_threads(before);
}

#[test]
fn sharded_distill_step_matches_unsharded_and_is_thread_stable() {
    let _g = lock();
    let before = threadpool::threads();
    let m = Manifest::builtin();
    let student = m.cfg("gpt_nano").unwrap().clone();
    let teacher = m.cfg("gpt_nano_lv2").unwrap().clone();
    let spec = m.artifact("distill_step__gpt_nano__gpt_nano_lv2").unwrap().clone();
    let state = host_state(&student, 11);
    let theta_t = init_theta(&teacher, 19);
    let toks = tokens_of(&student, 53);
    let run = |be: &dyn Backend| {
        let out = be
            .execute(
                &spec,
                &[
                    Arg::F32(&state, vec![student.state_len()]),
                    Arg::F32(&theta_t, vec![teacher.n_params]),
                    Arg::I32(&toks, vec![student.batch, student.seq_len]),
                    Arg::Scalar(0.5),
                    Arg::Scalar(1e-3),
                    Arg::Scalar(1.0),
                ],
            )
            .unwrap();
        be.read_f32(&out).unwrap()
    };
    let want = run(&ReferenceBackend::new(&m));
    for replicas in [2usize, 3, 4] {
        let be = ShardedBackend::new(&m, replicas);
        threadpool::set_threads(2);
        let t2 = run(&be);
        threadpool::set_threads(8);
        let t8 = run(&be);
        assert_eq!(bits(&t2), bits(&t8), "distill R={replicas} thread-dependent");
        assert_state_close(&t2, &want, &format!("distill R={replicas}"));
    }
    threadpool::set_threads(before);
}

#[test]
fn sharded_attn_maps_probe_is_bitwise_identical_to_full_batch() {
    let _g = lock();
    let m = Manifest::builtin();
    let cfg = m.cfg("bert_base_sim").unwrap().clone();
    let spec = m.artifact("attn_maps__bert_base_sim").unwrap().clone();
    let state = host_state(&cfg, 3);
    let toks = tokens_of(&cfg, 7);
    let run = |be: &dyn Backend| {
        let out = be
            .execute(
                &spec,
                &[
                    Arg::F32(&state, vec![cfg.state_len()]),
                    Arg::I32(&toks, vec![cfg.batch, cfg.seq_len]),
                ],
            )
            .unwrap();
        be.read_f32(&out).unwrap()
    };
    let want = run(&ReferenceBackend::new(&m));
    let be = ShardedBackend::new(&m, 4);
    let got = run(&be);
    assert_eq!(want.len(), cfg.n_layer * cfg.n_head * cfg.seq_len * cfg.seq_len);
    assert_eq!(
        bits(&got),
        bits(&want),
        "sharded attention probe diverged from the full-batch probe"
    );
}

#[test]
fn overlapped_train_step_is_bit_identical_to_post_barrier_reduce() {
    // Reproduce the PR 3 post-barrier pipeline by hand — shard grads on
    // separate replicas, barrier, tree_weighted_sum, apply_adamw — and pin
    // the overlapped backend path against it bit-for-bit.
    let _g = lock();
    let m = Manifest::builtin();
    let cfg = m.cfg("gpt_base_sim").unwrap().clone();
    let step_spec = m.artifact("train_step__gpt_base_sim").unwrap().clone();
    let grad_spec = m.artifact("train_grad__gpt_base_sim").unwrap().clone();
    let state = host_state(&cfg, 29);
    let toks = tokens_of(&cfg, 37);
    for r_eff in [2usize, 3, 4] {
        // overlapped path (the backend)
        let be = ShardedBackend::new(&m, r_eff);
        let out = be
            .execute(
                &step_spec,
                &[
                    Arg::F32(&state, vec![cfg.state_len()]),
                    Arg::I32(&toks, vec![cfg.batch, cfg.seq_len]),
                    Arg::Scalar(1e-3),
                    Arg::Scalar(1.0),
                ],
            )
            .unwrap();
        let got = be.read_f32(&out).unwrap();

        // post-barrier oracle
        let reference = ReferenceBackend::new(&m);
        let b = cfg.batch;
        let theta = &state[1..1 + cfg.n_params];
        let mut parts = Vec::new();
        let mut counts = Vec::new();
        for r in 0..r_eff {
            let (r0, r1) = (r * b / r_eff, (r + 1) * b / r_eff);
            let shard = &toks[r0 * cfg.seq_len..r1 * cfg.seq_len];
            let out = reference
                .execute(
                    &grad_spec,
                    &[
                        Arg::F32(theta, vec![cfg.n_params]),
                        Arg::I32(shard, vec![r1 - r0, cfg.seq_len]),
                    ],
                )
                .unwrap();
            parts.push(reference.read_f32(&out).unwrap());
            counts.push((r1 - r0) * (cfg.seq_len - 1));
        }
        let total: usize = counts.iter().sum();
        let weights: Vec<f32> = counts.iter().map(|&c| c as f32 / total as f32).collect();
        let reduced = allreduce::tree_weighted_sum(parts, &weights).unwrap();
        let want = allreduce::apply_adamw(&state, &reduced[1..], reduced[0], 1e-3, 1.0).unwrap();
        assert_eq!(
            bits(&got),
            bits(&want),
            "R={r_eff}: overlapped reduce diverged from the post-barrier pipeline"
        );
    }
}
