//! End-to-end table benchmarks: one reduced-size run per paper table /
//! figure family, on the nano configs, timing the full method programs the
//! experiment drivers execute at full size. `cargo bench` therefore
//! exercises every paper artifact's code path in minutes.

use std::time::Instant;

use multilevel::coordinator::{savings_vs_scratch, Harness, Method, RunOpts};
use multilevel::runtime::Runtime;

fn time_method(h: &Harness<'_>, m: &Method) -> (f64, multilevel::coordinator::Curve) {
    let t0 = Instant::now();
    let curve = h.run_method(m, None).unwrap();
    (t0.elapsed().as_secs_f64(), curve)
}

fn main() {
    let rt = Runtime::load_default().expect("runtime init");
    println!("== bench_tables (nano-scale versions of every table) ==");

    // Table 1/2 family: all methods on a language model
    let mut opts = RunOpts::quick("gpt_nano", 120);
    opts.eval_every = 20;
    opts.budget_mult = 1.0;
    let h = Harness::new(&rt, opts);
    let (t_scratch, scratch) = time_method(&h, &Method::Scratch);
    println!("tab1/2  Scratch            {t_scratch:7.2}s");
    for m in [
        Method::StackBert,
        Method::Bert2Bert,
        Method::LiGO { fit: false },
        Method::NetExpansion,
        Method::VCycle { levels: 2, fit: false },
    ] {
        let (dt, curve) = time_method(&h, &m);
        let s = savings_vs_scratch(&scratch, &curve, "gpt_nano");
        println!(
            "tab1/2  {:18} {dt:7.2}s  flops-saving {:+6.1}%",
            m.label(),
            s.flops * 100.0
        );
    }

    // Table 3 family: ViT
    let mut vopts = RunOpts::quick("vit_nano", 100);
    vopts.eval_every = 20;
    vopts.budget_mult = 1.0;
    let hv = Harness::new(&rt, vopts);
    let (dt, _) = time_method(&hv, &Method::VCycle { levels: 2, fit: false });
    println!("tab3    V-cycle (ViT)      {dt:7.2}s");

    // Table 4 family: KI + distillation path
    let (dt, _) = time_method(&h, &Method::KI);
    println!("tab1ki  KI                 {dt:7.2}s");

    // Table 5 family: custom-size V-cycle
    let t0 = Instant::now();
    h.run_vcycle_esmall(40, None).unwrap();
    println!("tab5    custom E_small     {:7.2}s", t0.elapsed().as_secs_f64());

    // Fig. 6 family: de-coalesced-only program
    let (dt, _) = time_method(&h, &Method::DecoalescedOnly);
    println!("fig6    De-coalesced only  {dt:7.2}s");
}
