//! Data-pipeline benchmarks: batch synthesis must be far cheaper than the
//! XLA step it feeds (L3 must never starve the device).

use std::time::Duration;

use multilevel::data::{Batcher, Corpus, VisionGen};
use multilevel::runtime::Runtime;
use multilevel::util::bench::{black_box, run};

fn main() {
    let rt = Runtime::load_default().expect("runtime init");
    println!("== bench_data ==");

    for name in ["gpt_base_sim", "bert_base_sim", "gpt_e2e"] {
        let cfg = rt.cfg(name).unwrap().clone();
        let corpus = Corpus::new(cfg.vocab, 0);
        let mut b = Batcher::new(&cfg, corpus, 1);
        let stats = run(&format!("batch gen {name}"), Duration::from_millis(600), || {
            black_box(b.next_batch());
        });
        let step_est = cfg.flops_train_step / 23e9;
        println!(
            "  -> {:.3}% of a train step",
            100.0 * stats.mean.as_secs_f64() / step_est
        );
    }

    let cfg = rt.cfg("vit_b_sim").unwrap().clone();
    let mut g = VisionGen::new(&cfg, 0, 1);
    run("image batch gen vit_b_sim", Duration::from_millis(600), || {
        black_box(g.next_batch(cfg.batch));
    });

    // corpus primitives
    let corpus = Corpus::new(512, 0);
    let mut rng = multilevel::util::rng::Rng::new(5);
    run("corpus sequence(32)", Duration::from_millis(300), || {
        black_box(corpus.sequence(32, &mut rng));
    });
}
