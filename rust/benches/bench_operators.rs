//! Operator benchmarks: coalesce / refine / interp latency per level pair —
//! the paper's claim that level-transition overhead is negligible (App. C)
//! quantified on this substrate.

use std::time::Duration;

use multilevel::coordinator::operators;
use multilevel::runtime::{init_state, Runtime};
use multilevel::util::bench::{black_box, run};

fn main() {
    let rt = Runtime::load_default().expect("runtime init");
    println!("== bench_operators ==");

    let pairs = [
        ("gpt_nano", "gpt_nano_lv2"),
        ("bert_base_sim", "bert_base_sim_lv2"),
        ("bert_large_sim", "bert_large_sim_lv2"),
        ("gpt_e2e", "gpt_e2e_lv2"),
    ];
    for (big, small) in pairs {
        let cfg = rt.cfg(big).unwrap().clone();
        let state = init_state(&rt, &cfg, 1).unwrap();
        let small_state = operators::coalesce(&rt, big, small, &state).unwrap();

        let c = run(&format!("coalesce {big}"), Duration::from_secs(1), || {
            black_box(operators::coalesce(&rt, big, small, &state).unwrap());
        });
        let r = run(&format!("refine   {big}"), Duration::from_secs(1), || {
            black_box(
                operators::refine(&rt, big, small, &state, &small_state, 0.25, false)
                    .unwrap(),
            );
        });
        run(&format!("interp   {big}"), Duration::from_secs(1), || {
            black_box(operators::interp_states(&rt, big, &state, &state, 0.5).unwrap());
        });
        // transition cost in units of train steps (App. C argument)
        let steps_equiv = (c.mean + r.mean).as_secs_f64()
            / (cfg.flops_train_step / 23e9).max(1e-9);
        println!(
            "  -> one full transition ≈ {steps_equiv:.2} train-step equivalents (at 23 GFLOP/s)"
        );
    }
}
