//! Runtime micro-benchmarks: the L3 hot-path pieces in isolation —
//! artifact compile, host→device upload, train-step dispatch, loss read,
//! eval. These are the §Perf numbers of EXPERIMENTS.md.

use std::time::Duration;

use multilevel::coordinator::Trainer;
use multilevel::runtime::{init_state, Runtime};
use multilevel::util::bench::{black_box, run};

fn main() {
    let rt = Runtime::load_default().expect("runtime init");
    println!("== bench_runtime ==");
    println!("device: {}", rt.device_info());
    let (replicas, threads_per) = rt.shard_topology();
    println!("topology: {replicas} replicas x {threads_per} threads-per-replica");

    // one explicit cold compile (the cache makes repeats meaningless)
    let t0 = std::time::Instant::now();
    rt.exe("train_step__gpt_nano").unwrap();
    println!("cold compile train_step__gpt_nano: {:?}", t0.elapsed());

    run("exe cache hit", Duration::from_millis(300), || {
        black_box(rt.exe("train_step__gpt_nano").unwrap());
    });

    let tokens = vec![1i32; 4 * 16];
    run("upload i32[4,16]", Duration::from_millis(500), || {
        black_box(rt.upload_i32(&tokens, &[4, 16]).unwrap());
    });
    let state_host = vec![0f32; 3 * 30144 + 1];
    run("upload f32[90433] (nano state)", Duration::from_millis(500), || {
        black_box(rt.upload_f32(&state_host, &[3 * 30144 + 1]).unwrap());
    });

    for cfg_name in ["gpt_nano", "gpt_base_sim", "bert_base_sim"] {
        let cfg = rt.cfg(cfg_name).unwrap().clone();
        let mut state = init_state(&rt, &cfg, 1).unwrap();
        let mut trainer = Trainer::new(&rt, cfg_name, 0, 2, 2).unwrap();
        let (s, _) = trainer.step(&rt, &state, 1e-3, 1).unwrap(); // warm
        state = s;
        let mut step = 1usize;
        let stats = run(
            &format!("train_step {cfg_name}"),
            Duration::from_secs(2),
            || {
                step += 1;
                let (s, _) = trainer.step(&rt, &state, 1e-3, step).unwrap();
                state = s;
            },
        );
        println!(
            "  -> {:.2} GFLOP/s analytic",
            cfg.flops_train_step / stats.mean.as_secs_f64() / 1e9
        );
        run(&format!("loss read {cfg_name}"), Duration::from_millis(500), || {
            black_box(state.loss(&rt).unwrap());
        });
        run(&format!("eval(2 batches) {cfg_name}"), Duration::from_secs(1), || {
            black_box(trainer.eval(&rt, &state).unwrap());
        });
    }

    // data-parallel train step: replica scaling of the sharded backend
    for replicas in [2usize, 4] {
        let srt = Runtime::sharded(replicas);
        let (r, t) = srt.shard_topology();
        let cfg = srt.cfg("gpt_base_sim").unwrap().clone();
        let mut state = init_state(&srt, &cfg, 1).unwrap();
        let mut trainer = Trainer::new(&srt, "gpt_base_sim", 0, 2, 2).unwrap();
        let (s, _) = trainer.step(&srt, &state, 1e-3, 1).unwrap(); // warm
        state = s;
        let mut step = 1usize;
        let stats = run(
            &format!("train_step gpt_base_sim sharded {r}x{t}"),
            Duration::from_secs(2),
            || {
                step += 1;
                let (s, _) = trainer.step(&srt, &state, 1e-3, step).unwrap();
                state = s;
            },
        );
        println!(
            "  -> {:.2} GFLOP/s analytic",
            cfg.flops_train_step / stats.mean.as_secs_f64() / 1e9
        );
    }
}
